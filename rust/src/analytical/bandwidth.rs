//! The effective-bandwidth surface `BW = f(N_p, S_i)` — Eq. 8.
//!
//! The paper quantifies `f` empirically (Fig. 3) by measuring the average
//! effective bandwidth of one PE array against block size and array
//! count. We do the same measurement against the DDR model once, cache
//! the grid, and interpolate log-linearly in `S_i` between grid points
//! (bandwidth varies smoothly with burst length).

use std::collections::BTreeMap;

use crate::ddr::{DdrConfig, DdrSim};

/// Grid of `S_i` sample points (powers of two, the paper's sweep).
pub const SI_GRID: [usize; 9] = [4, 8, 16, 32, 64, 128, 256, 512, 1024];

/// Calibrated per-array bandwidth surface (bytes/s).
#[derive(Debug, Clone)]
pub struct BandwidthSurface {
    /// np -> (si -> bytes/s), measured.
    grid: BTreeMap<usize, BTreeMap<usize, f64>>,
}

impl BandwidthSurface {
    /// Measure the Fig. 3 grid on the DDR model for `np` in {1, 2, 4}.
    pub fn calibrate(ddr: &DdrConfig) -> Self {
        Self::calibrate_for(ddr, &[1, 2, 4])
    }

    pub fn calibrate_for(ddr: &DdrConfig, nps: &[usize]) -> Self {
        let mut grid = BTreeMap::new();
        for &np in nps {
            let mut row = BTreeMap::new();
            for &si in &SI_GRID {
                row.insert(si, DdrSim::block_bandwidth(ddr, np, si).per_master);
            }
            grid.insert(np, row);
        }
        Self { grid }
    }

    /// Build from explicit measurements (e.g. replaying the paper's own
    /// Fig. 3 numbers instead of the DDR model).
    pub fn from_points(points: &[(usize, usize, f64)]) -> Self {
        let mut grid: BTreeMap<usize, BTreeMap<usize, f64>> = BTreeMap::new();
        for &(np, si, bw) in points {
            grid.entry(np).or_default().insert(si, bw);
        }
        Self { grid }
    }

    /// Per-array effective bandwidth for `(np, si)`, bytes/s.
    /// `np` snaps to the nearest calibrated array count; `si` interpolates
    /// linearly between grid points (clamped at the ends).
    pub fn bw(&self, np: usize, si: usize) -> f64 {
        let row = self
            .grid
            .iter()
            .min_by_key(|(k, _)| k.abs_diff(np))
            .map(|(_, v)| v)
            .expect("empty bandwidth surface");
        let (&lo_si, &lo_bw) = match row.range(..=si).next_back() {
            Some(kv) => kv,
            None => return *row.values().next().unwrap(),
        };
        let (&hi_si, &hi_bw) = match row.range(si..).next() {
            Some(kv) => kv,
            None => return lo_bw,
        };
        if hi_si == lo_si {
            return lo_bw;
        }
        let t = (si - lo_si) as f64 / (hi_si - lo_si) as f64;
        lo_bw + t * (hi_bw - lo_bw)
    }

    /// The calibrated grid, for reports and the Fig. 3 bench.
    pub fn points(&self) -> Vec<(usize, usize, f64)> {
        self.grid
            .iter()
            .flat_map(|(&np, row)| row.iter().map(move |(&si, &bw)| (np, si, bw)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn surface() -> BandwidthSurface {
        BandwidthSurface::calibrate(&DdrConfig::vc709())
    }

    #[test]
    fn fig3_shape_monotone_in_si() {
        let s = surface();
        for np in [1, 2, 4] {
            assert!(s.bw(np, 16) < s.bw(np, 64));
            assert!(s.bw(np, 64) < s.bw(np, 256));
        }
    }

    #[test]
    fn fig3_shape_declines_with_np() {
        let s = surface();
        for si in [16, 64, 256] {
            assert!(s.bw(1, si) > s.bw(2, si));
            assert!(s.bw(2, si) > s.bw(4, si));
        }
    }

    #[test]
    fn interpolation_between_grid_points() {
        let s = surface();
        let mid = s.bw(2, 96);
        assert!(mid > s.bw(2, 64) && mid < s.bw(2, 128));
    }

    #[test]
    fn clamps_outside_grid() {
        let s = surface();
        assert_eq!(s.bw(2, 1), s.bw(2, 4));
        assert_eq!(s.bw(2, 100_000), s.bw(2, 1024));
    }

    #[test]
    fn np_snaps_to_nearest() {
        let s = surface();
        assert_eq!(s.bw(3, 64), s.bw(2, 64)); // ties break low
    }

    #[test]
    fn from_points_roundtrip() {
        let s = BandwidthSurface::from_points(&[(1, 64, 2e9), (1, 128, 3e9)]);
        assert_eq!(s.bw(1, 64), 2e9);
        assert_eq!(s.bw(1, 96), 2.5e9);
    }
}
