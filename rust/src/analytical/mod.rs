//! The analytical performance model — Section IV, Eqs. 3–9.
//!
//! Given a problem `(M, K, N)`, a hardware config and a run config
//! `⟨N_p, S_i⟩`, the model predicts the per-array workload (Eq. 3), the
//! data-transfer time (Eqs. 4–5, using the effective bandwidth surface
//! `BW = f(N_p, S_i)` of Eq. 8 measured on the DDR model), the compute
//! time (Eq. 6) and the `T_total` bounds of Eq. 7. Eq. 9 prunes the
//! design space: chaining trades array count for array length, so `S_i`
//! caps the feasible `N_p`.
//!
//! [`strassen`] layers an algorithmic question on top: given those
//! per-problem time predictions, when does one level of Strassen
//! recursion (7 half-size products plus O(n²) combine traffic) beat the
//! direct multi-array run? [`strassen_crossover`] answers per level and
//! hands the planner its recursion cutoff.

pub mod bandwidth;
pub mod strassen;

pub use bandwidth::BandwidthSurface;
pub use strassen::{
    strassen_crossover, strassen_crossover_dtype, strassen_crossover_with, CrossoverPlan,
    StrassenAlgo,
};


use crate::blocking::BlockPlan;
use crate::config::{HardwareConfig, RunConfig};
use crate::gemm::Dtype;
use crate::mpe::timing::TaskTiming;

/// Everything Eqs. 3–7 say about one `(problem, config)` pair.
#[derive(Debug, Clone, Copy)]
pub struct Prediction {
    /// Tasks per array (Eq. 3).
    pub n_work: usize,
    /// Effective per-array bandwidth used (Eq. 8), bytes/s.
    pub bw: f64,
    /// Seconds to move one task's data (Eq. 4).
    pub t_work: f64,
    /// Per-array transfer time (Eq. 5).
    pub t_trans: f64,
    /// Per-array compute time (Eq. 6).
    pub t_compute: f64,
    /// Eq. 7 bounds on `T_total`.
    pub lower: f64,
    pub upper: f64,
}

impl Prediction {
    /// Overlap estimate: with double buffering, steady state is governed
    /// by the slower of the two engines. Always within the Eq. 7 bounds;
    /// this is what the DSE ranks by and what Fig. 4's "estimated" series
    /// brackets.
    pub fn t_overlap(&self) -> f64 {
        self.t_compute.max(self.t_trans)
    }

    /// Is this configuration memory-bound (transfer dominates compute)?
    pub fn memory_bound(&self) -> bool {
        self.t_trans > self.t_compute
    }

    /// Bandwidth one array *needs* for full overlap (bytes/s): move one
    /// task's bytes in one task's compute time.
    pub fn required_bw(&self) -> f64 {
        if self.t_compute == 0.0 {
            return f64::INFINITY;
        }
        self.t_trans / self.t_compute * self.bw
    }

    /// GFLOPS estimates for a problem with `flops` useful FLOPs.
    pub fn gflops_from(&self, flops: u64) -> f64 {
        flops as f64 / self.t_overlap() / 1e9
    }
}

/// Eq. 3: average sub-block multiplications per array.
pub fn n_work(m: usize, n: usize, si: usize, sj: usize, np: usize) -> usize {
    (m.div_ceil(si) * n.div_ceil(sj)).div_ceil(np)
}

/// Eq. 4: seconds to load `SA_i`, `SB_j` and write `C_ij` at bandwidth
/// `bw` (bytes/s).
pub fn t_work(si: usize, sj: usize, k: usize, bw: f64) -> f64 {
    4.0 * (si as f64 * k as f64 + sj as f64 * k as f64 + si as f64 * sj as f64) / bw
}

/// Relative per-MAC DSP cost of one fused multiply-add at `dtype`,
/// normalized to the f32 pipeline the paper synthesizes (2 DSP48E1
/// slices per f32 FMA on the VC709). A double-precision FMA consumes
/// roughly 2.3× the DSP budget (wider partial products, deeper
/// alignment); a half-input FMA that widens to f32 accumulate saves the
/// multiplier array's LSB half but keeps the f32 adder — about 0.65×.
pub fn mac_cost(dtype: Dtype) -> f64 {
    match dtype {
        Dtype::F64 => 2.28,
        Dtype::F32 => 1.0,
        Dtype::F16 | Dtype::Bf16 => 0.65,
    }
}

/// Eq. 4 at reduced (or extended) operand precision: `SA_i` and `SB_j`
/// move at `dtype`'s element width while the `C_ij` writeback stays
/// f32 (the accumulate-in-f32 pipeline streams f32 results regardless
/// of operand precision). Collapses to [`t_work`] exactly at `F32`.
pub fn t_work_dtype(si: usize, sj: usize, k: usize, bw: f64, dtype: Dtype) -> f64 {
    let operand_bytes = dtype.bytes() as f64 * (si as f64 * k as f64 + sj as f64 * k as f64);
    let c_bytes = 4.0 * si as f64 * sj as f64;
    (operand_bytes + c_bytes) / bw
}

/// Full model evaluation, Eqs. 3–7.
pub fn predict(
    hw: &HardwareConfig,
    run: &RunConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
) -> anyhow::Result<Prediction> {
    run.validate(hw)?;
    let nw = n_work(m, n, run.si, run.sj, run.np);
    let bw = surface.bw(run.np, run.si);
    let tw = t_work(run.si, run.sj, k, bw);
    let t_trans = nw as f64 * tw;
    let t_compute = nw as f64
        * TaskTiming::per_task(run.si, run.sj, k, hw.fmac_stages).total() as f64
        / (hw.freq_mhz * 1e6);
    Ok(Prediction {
        n_work: nw,
        bw,
        t_work: tw,
        t_trans,
        t_compute,
        lower: t_compute,
        upper: t_trans + t_compute,
    })
}

/// [`predict`] with a per-precision cost model: the transfer term uses
/// [`t_work_dtype`] (operands at `dtype` width, f32 `C` writeback) and
/// the compute term scales by [`mac_cost`] — a wider MAC runs
/// proportionally fewer PEs at the same DSP budget, a narrower one
/// proportionally more. Identical to [`predict`] at `F32`; the DSE
/// prices `(config, dtype)` pairs with this.
pub fn predict_dtype(
    hw: &HardwareConfig,
    run: &RunConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
    dtype: Dtype,
) -> anyhow::Result<Prediction> {
    run.validate(hw)?;
    let nw = n_work(m, n, run.si, run.sj, run.np);
    let bw = surface.bw(run.np, run.si);
    let tw = t_work_dtype(run.si, run.sj, k, bw, dtype);
    let t_trans = nw as f64 * tw;
    let t_compute = mac_cost(dtype)
        * nw as f64
        * TaskTiming::per_task(run.si, run.sj, k, hw.fmac_stages).total() as f64
        / (hw.freq_mhz * 1e6);
    Ok(Prediction {
        n_work: nw,
        bw,
        t_work: tw,
        t_trans,
        t_compute,
        lower: t_compute,
        upper: t_trans + t_compute,
    })
}

/// Eq. 9: the feasible `N_p` values for a block size `S_i`, given the
/// hardware's `P_m` and `P`. An `N_p`-array run chains `P_m / N_p` base
/// arrays into each logical array of `P_m * P / N_p` PEs, which must hold
/// at least `S_i` PEs.
pub fn feasible_nps(hw: &HardwareConfig, si: usize) -> Vec<usize> {
    (0..)
        .map(|e| 1usize << e)
        .take_while(|np| *np <= hw.pm)
        .filter(|np| hw.pm % np == 0 && si <= hw.total_pes() / np)
        .collect()
}

/// GFLOPS the paper reports: useful FLOPs of the *problem* over the
/// whole-accelerator time estimate.
pub fn estimated_gflops(
    hw: &HardwareConfig,
    run: &RunConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
) -> anyhow::Result<f64> {
    let p = predict(hw, run, m, k, n, surface)?;
    let plan = BlockPlan::new(m, k, n, run.si, run.sj);
    Ok(p.gflops_from(plan.effective_flops()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    fn surface() -> BandwidthSurface {
        BandwidthSurface::calibrate(&HardwareConfig::paper().ddr)
    }

    #[test]
    fn eq3_matches_paper_conv2() {
        // conv-2 at (2, 128): ceil(128/128)*ceil(729/128) = 6 tasks, 3/array.
        assert_eq!(n_work(128, 729, 128, 128, 2), 3);
        assert_eq!(n_work(128, 729, 128, 128, 4), 2);
        assert_eq!(n_work(128, 729, 128, 128, 1), 6);
    }

    #[test]
    fn eq4_byte_count() {
        let bw = 1e9;
        let t = t_work(128, 128, 1200, bw);
        let bytes = 4.0 * (128.0 * 1200.0 + 128.0 * 1200.0 + 128.0 * 128.0);
        assert!((t - bytes / bw).abs() < 1e-15);
    }

    #[test]
    fn dtype_model_f32_matches_base_and_widths_order() {
        let hw = HardwareConfig::paper();
        let s = surface();
        let run = RunConfig::square(2, 128);
        let base = predict(&hw, &run, 128, 1200, 729, &s).unwrap();
        let f32d = predict_dtype(&hw, &run, 128, 1200, 729, &s, Dtype::F32).unwrap();
        assert_eq!(base.t_trans.to_bits(), f32d.t_trans.to_bits(), "F32 is the base model");
        assert_eq!(base.t_compute.to_bits(), f32d.t_compute.to_bits());
        // Narrower operands move less, wider ones more; compute scales
        // with the MAC cost table in the same order.
        let f64d = predict_dtype(&hw, &run, 128, 1200, 729, &s, Dtype::F64).unwrap();
        let bf16 = predict_dtype(&hw, &run, 128, 1200, 729, &s, Dtype::Bf16).unwrap();
        assert!(bf16.t_trans < f32d.t_trans && f32d.t_trans < f64d.t_trans);
        assert!(bf16.t_compute < f32d.t_compute && f32d.t_compute < f64d.t_compute);
    }

    #[test]
    fn t_work_dtype_byte_count_keeps_f32_writeback() {
        let bw = 1e9;
        let t = t_work_dtype(128, 128, 1200, bw, Dtype::Bf16);
        let bytes = 2.0 * (128.0 * 1200.0 + 128.0 * 1200.0) + 4.0 * 128.0 * 128.0;
        assert!((t - bytes / bw).abs() < 1e-15);
        let t32 = t_work_dtype(128, 128, 1200, bw, Dtype::F32);
        assert_eq!(t32.to_bits(), t_work(128, 128, 1200, bw).to_bits());
    }

    #[test]
    fn eq7_bounds_order() {
        let hw = HardwareConfig::paper();
        let s = surface();
        let p = predict(&hw, &RunConfig::square(2, 128), 128, 1200, 729, &s).unwrap();
        assert!(p.lower <= p.t_overlap());
        assert!(p.t_overlap() <= p.upper);
        assert!(p.lower > 0.0);
    }

    #[test]
    fn eq9_pruning() {
        let hw = HardwareConfig::paper(); // Pm=4, P=64
        assert_eq!(feasible_nps(&hw, 32), vec![1, 2, 4]);
        assert_eq!(feasible_nps(&hw, 64), vec![1, 2, 4]);
        assert_eq!(feasible_nps(&hw, 65), vec![1, 2]);
        assert_eq!(feasible_nps(&hw, 128), vec![1, 2]);
        assert_eq!(feasible_nps(&hw, 129), vec![1]);
        assert_eq!(feasible_nps(&hw, 256), vec![1]);
        assert_eq!(feasible_nps(&hw, 257), Vec::<usize>::new());
    }

    #[test]
    fn small_blocks_are_memory_bound() {
        // The Fig. 4 observation: (2, 16) on conv-2 is memory-bound.
        let hw = HardwareConfig::paper();
        let s = surface();
        let p16 = predict(&hw, &RunConfig::square(2, 16), 128, 1200, 729, &s).unwrap();
        assert!(p16.memory_bound());
        let p128 = predict(&hw, &RunConfig::square(2, 128), 128, 1200, 729, &s).unwrap();
        assert!(!p128.memory_bound() || p128.t_trans < 1.5 * p128.t_compute);
    }

    #[test]
    fn gflops_reasonable_for_fc6() {
        // fc-6 at the paper's optimum (2, 128) should approach the
        // 102.4 GFLOPS peak (paper reports 100.9 at 98.6% efficiency).
        let hw = HardwareConfig::paper();
        let s = surface();
        let g = estimated_gflops(&hw, &RunConfig::square(2, 128), 128, 9216, 4096, &s)
            .unwrap();
        assert!(g > 80.0 && g <= hw.peak_gflops() * 1.01, "{g}");
    }

    #[test]
    fn required_bw_marks_the_overlap_break_even() {
        let hw = HardwareConfig::paper();
        let s = surface();
        let p = predict(&hw, &RunConfig::square(2, 128), 128, 1200, 729, &s).unwrap();
        // required_bw is the bandwidth at which t_trans == t_compute:
        // re-evaluating t_work at that bandwidth must equal t_compute/n.
        let t_at_required = t_work(128, 128, 1200, p.required_bw());
        let t_compute_per_task = p.t_compute / p.n_work as f64;
        assert!((t_at_required - t_compute_per_task).abs() / t_compute_per_task < 1e-9);
    }

    #[test]
    fn gflops_from_is_flops_over_overlap() {
        let hw = HardwareConfig::paper();
        let s = surface();
        let p = predict(&hw, &RunConfig::square(2, 128), 128, 9216, 4096, &s).unwrap();
        let flops = 2u64 * 128 * 9216 * 4096;
        let g = p.gflops_from(flops);
        assert!((g - flops as f64 / p.t_overlap() / 1e9).abs() < 1e-9);
    }

    #[test]
    fn feasible_nps_respects_tiny_hardware() {
        let hw = HardwareConfig::tiny(); // Pm=2, P=8 -> 16 PEs
        assert_eq!(feasible_nps(&hw, 8), vec![1, 2]);
        assert_eq!(feasible_nps(&hw, 9), vec![1]);
        assert_eq!(feasible_nps(&hw, 16), vec![1]);
        assert_eq!(feasible_nps(&hw, 17), Vec::<usize>::new());
    }

    #[test]
    fn larger_si_needs_less_bandwidth_per_flop() {
        // Eq. 4 / Eq. 6: bytes per task ~ 2*Si*K, flops ~ 2*Si^2*K —
        // doubling Si halves bytes-per-flop, the root of Fig. 4's shape.
        let bw = 1e9;
        let per_flop =
            |si: usize| t_work(si, si, 1000, bw) / (2.0 * (si * si * 1000) as f64);
        assert!(per_flop(128) < per_flop(64));
        assert!(per_flop(64) < per_flop(32));
    }

    #[test]
    fn prop_bounds_always_ordered() {
        let hw = HardwareConfig::paper();
        let s = surface();
        check::cases(48, |rng| {
            let np = 1usize << rng.range(0, 3);
            let si = 1usize << rng.range(4, 8);
            if si > hw.total_pes() / np {
                return;
            }
            let (m, k, n) =
                (rng.range(1, 2000), rng.range(1, 4000), rng.range(1, 2000));
            let p = predict(&hw, &RunConfig::square(np, si), m, k, n, &s).unwrap();
            assert!(p.lower <= p.upper);
            assert!(p.lower <= p.t_overlap() && p.t_overlap() <= p.upper);
            assert!(p.t_work > 0.0);
        });
    }

    #[test]
    fn prop_n_work_eq3_identity() {
        check::cases(48, |rng| {
            let (m, n) = (rng.range(1, 3000), rng.range(1, 3000));
            let si = rng.range(1, 300);
            let np = rng.range(1, 5);
            let nw = n_work(m, n, si, si, np);
            let tasks = m.div_ceil(si) * n.div_ceil(si);
            assert!(nw * np >= tasks);
            assert!(nw <= tasks);
        });
    }
}
