//! Offline stub of the `xla` (xla-rs / PJRT) bindings.
//!
//! The build environment has no XLA C API distribution, so this crate
//! provides the exact type/method surface `multi_array::runtime` needs,
//! with every entry point that would touch PJRT failing fast with a
//! descriptive [`Error`]. The effect at the crate level: `Runtime::load`
//! returns `Err`, `NumericsEngine::pjrt` propagates it, and
//! `NumericsEngine::auto` falls back to the in-process golden/packed
//! backend — numerics stay correct, only the PJRT execution path is
//! dark. Swapping this path dependency for the real `xla` crate (plus
//! its `libpjrt` closure) re-enables it without touching the main crate.

use std::fmt;

/// Error type mirroring `xla::Error` (Display + std::error::Error).
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
}

impl Error {
    fn unavailable(what: &str) -> Self {
        Self {
            message: format!(
                "{what}: PJRT unavailable (vendored `xla` stub — link the real xla-rs \
                 bindings to enable the PJRT backend)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for Error {}

/// Stub of `xla::PjRtClient`. [`PjRtClient::cpu`] always errors.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Stub of `xla::PjRtLoadedExecutable`. Unreachable in practice: no
/// client can be constructed to compile one.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub of `xla::Literal` (host-side tensor value).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal { _private: () })
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_fast_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn hlo_loading_fails() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn literal_construction_is_inert() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_ok());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
