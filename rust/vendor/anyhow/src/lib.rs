//! Vendored minimal re-implementation of the `anyhow` API surface this
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build environment is offline (no crates.io), so instead of the
//! real crate we ship this string-chain error type. It intentionally
//! mirrors the real API closely enough that swapping the path dependency
//! for `anyhow = "1"` is a no-op for the main crate.

use std::error::Error as StdError;
use std::fmt;

/// A dynamically typed error: an outermost message plus a context chain
/// (outermost first), flattened to strings at wrap time.
pub struct Error {
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>` — the workspace-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (the `Context` trait calls
    /// this; also available directly, as on the real `anyhow::Error`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion from
// every std error type coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(::std::format!($($arg)+))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: `",
                ::std::stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse().with_context(|| format!("bad number {s:?}"))?;
        ensure!(n > 0, "want positive, got {n}");
        Ok(n)
    }

    #[test]
    fn happy_path() {
        assert_eq!(parse("7").unwrap(), 7);
    }

    #[test]
    fn context_wraps_parse_error() {
        let e = parse("x").unwrap_err();
        assert!(e.to_string().contains("bad number"));
        assert!(e.chain().count() >= 2);
    }

    #[test]
    fn ensure_formats_message() {
        let e = parse("0").unwrap_err();
        assert_eq!(e.to_string(), "want positive, got 0");
    }

    #[test]
    fn bail_and_anyhow() {
        fn f() -> Result<()> {
            bail!("failed with code {}", 3)
        }
        assert_eq!(f().unwrap_err().to_string(), "failed with code 3");
    }

    #[test]
    fn from_io_error() {
        fn f() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(f().is_err());
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by") && dbg.contains("inner"));
    }
}
