//! Runtime tests against the real AOT artifacts. These exercise the
//! python→HLO→PJRT→rust bridge end to end; they skip (with a notice)
//! when `artifacts/` has not been built, so `cargo test` stays green in
//! a fresh checkout — run `make artifacts` first for full coverage.

use multi_array::config::HardwareConfig;
use multi_array::coordinator::{Coordinator, GemmJob, NumericsEngine};
use multi_array::gemm::Matrix;
use multi_array::runtime::Runtime;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn runtime_loads_all_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let shapes = rt.task_shapes();
    assert!(!shapes.is_empty());
    assert!(shapes.iter().any(|&(si, _, sj)| si == 128 && sj == 128));
}

#[test]
fn gemm_full_artifact_matches_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let a = Matrix::random(256, 256, 1);
    let b = Matrix::random(256, 256, 2);
    let got = rt.gemm_full(&a, &b).unwrap();
    let want = a.matmul(&b);
    assert!(
        got.allclose(&want, 1e-4),
        "max err {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn block_product_exact_panel() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    // Exactly one (128, 128, 128) task execution.
    let a = Matrix::random(128, 128, 3);
    let b = Matrix::random(128, 128, 4);
    let got = rt.block_product(&a, &b).unwrap();
    assert!(got.allclose(&a.matmul(&b), 1e-4));
}

#[test]
fn block_product_chunked_k() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    // K = 1200 (conv-2's depth): 1024-chunk + 128-chunks + padded tail.
    let a = Matrix::random(128, 1200, 5);
    let b = Matrix::random(1200, 128, 6);
    let got = rt.block_product(&a, &b).unwrap();
    let want = a.matmul(&b);
    assert!(
        got.allclose(&want, 1e-3),
        "max err {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn block_product_ragged_all_dims() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let a = Matrix::random(97, 201, 7);
    let b = Matrix::random(201, 55, 8);
    let got = rt.block_product(&a, &b).unwrap();
    assert!(got.allclose(&a.matmul(&b), 1e-3));
}

#[test]
fn block_product_tiny() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let a = Matrix::random(3, 5, 9);
    let b = Matrix::random(5, 2, 10);
    let got = rt.block_product(&a, &b).unwrap();
    assert!(got.allclose(&a.matmul(&b), 1e-4));
}

#[test]
fn coordinator_with_pjrt_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = NumericsEngine::pjrt(&dir).unwrap();
    assert_eq!(engine.name, "pjrt");
    let co = Coordinator::new(HardwareConfig::paper(), engine);
    let a = Matrix::random(150, 90, 11);
    let b = Matrix::random(90, 130, 12);
    let want = a.matmul(&b);
    let r = co.run_job(GemmJob { id: 1, a: a.into(), b: b.into(), run: None }).unwrap();
    assert!(
        r.c.allclose(&want, 1e-3),
        "max err {}",
        r.c.max_abs_diff(&want)
    );
}
