//! Cross-module integration tests: the full simulated accelerator against
//! golden numerics, model-vs-simulation agreement, DSE consistency, and
//! the work-stealing end-to-end behaviour.

use multi_array::accelerator::{Accelerator, SimOptions};
use multi_array::analytical::{self, BandwidthSurface};
use multi_array::blocking::BlockPlan;
use multi_array::cnn;
use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::coordinator::{Coordinator, GemmJob, NumericsEngine};
use multi_array::dse;
use multi_array::gemm::{blocked_matmul, Matrix};
use multi_array::mpe::LinearArray;

fn paper_acc() -> Accelerator {
    Accelerator::new(HardwareConfig::paper())
}

#[test]
fn stepped_array_equals_functional_equals_oracle() {
    // Three independent numerics paths agree: the cycle-stepped PE array,
    // the functional blocked algorithm, and the naive oracle.
    let a = Matrix::random(48, 30, 1);
    let b = Matrix::random(30, 40, 2);
    let oracle = a.matmul(&b);

    let functional = blocked_matmul(&a, &b, 16, 16);
    assert!(functional.allclose(&oracle, 1e-4));

    let arr = LinearArray::new(64, 14);
    let plan = BlockPlan::new(48, 30, 40, 16, 16);
    let mut c = Matrix::zeros(48, 40);
    for t in plan.tasks() {
        let sa = a.block(t.row0, 0, t.si, a.cols);
        let sb = b.block(0, t.col0, b.rows, t.sj);
        let exec = arr.execute_task(&sa, &sb, t.si, t.sj);
        c.set_block(t.row0, t.col0, &exec.result);
    }
    assert!(c.allclose(&oracle, 1e-4));
}

#[test]
fn simulated_time_within_model_bounds() {
    // Eq. 7 must bracket the event simulation for every feasible config
    // on conv-2 — the Fig. 4 claim.
    let hw = HardwareConfig::paper();
    let acc = paper_acc();
    let l = cnn::layer("conv2").unwrap();
    for si in [16usize, 32, 64, 128, 256] {
        for np in analytical::feasible_nps(&hw, si) {
            let run = RunConfig::square(np, si);
            let p = analytical::predict(&hw, &run, l.m, l.k, l.n, acc.surface()).unwrap();
            let sim = acc
                .simulate(&run, l.m, l.k, l.n, &SimOptions::default())
                .unwrap();
            // Allow the pipeline-fill transfer of the first task above
            // the pure-compute lower bound, and a small epsilon.
            assert!(
                sim.total_secs >= p.lower * 0.999,
                "({np},{si}): sim {} < lower {}",
                sim.total_secs,
                p.lower
            );
            assert!(
                sim.total_secs <= p.upper * 1.001 + p.t_work,
                "({np},{si}): sim {} > upper {}",
                sim.total_secs,
                p.upper
            );
        }
    }
}

#[test]
fn memory_bound_configs_sit_near_upper_bound() {
    // Fig. 4's second observation: when bandwidth is unsatisfied the
    // actual time approaches the upper bound, not the lower.
    let hw = HardwareConfig::paper();
    let acc = paper_acc();
    let l = cnn::layer("conv2").unwrap();
    let run = RunConfig::square(2, 16); // memory-bound case
    let p = analytical::predict(&hw, &run, l.m, l.k, l.n, acc.surface()).unwrap();
    assert!(p.memory_bound());
    let sim = acc.simulate(&run, l.m, l.k, l.n, &SimOptions::default()).unwrap();
    let to_lower = (sim.total_secs - p.lower).abs();
    let to_upper = (sim.total_secs - p.upper).abs();
    assert!(
        to_upper < to_lower,
        "memory-bound sim {} should be nearer upper {} than lower {}",
        sim.total_secs,
        p.upper,
        p.lower
    );
}

#[test]
fn compute_bound_configs_sit_near_lower_bound() {
    let hw = HardwareConfig::paper();
    let acc = paper_acc();
    let l = cnn::layer("fc6").unwrap();
    let run = RunConfig::square(2, 128); // the paper's optimum for fc6
    let p = analytical::predict(&hw, &run, l.m, l.k, l.n, acc.surface()).unwrap();
    assert!(!p.memory_bound());
    let sim = acc.simulate(&run, l.m, l.k, l.n, &SimOptions::default()).unwrap();
    let to_lower = (sim.total_secs - p.lower).abs();
    let to_upper = (sim.total_secs - p.upper).abs();
    assert!(to_lower < to_upper);
}

#[test]
fn fig4_crossover_1_32_beats_2_16() {
    // "the case of (Np,Si)=(1,32) achieves lower execution time than the
    // case of (Np,Si)=(2,16)" — both memory-bound, bigger blocks win.
    let acc = paper_acc();
    let l = cnn::layer("conv2").unwrap();
    let s132 = acc
        .simulate(&RunConfig::square(1, 32), l.m, l.k, l.n, &SimOptions::default())
        .unwrap();
    let s216 = acc
        .simulate(&RunConfig::square(2, 16), l.m, l.k, l.n, &SimOptions::default())
        .unwrap();
    assert!(
        s132.total_secs < s216.total_secs,
        "(1,32) {} should beat (2,16) {}",
        s132.total_secs,
        s216.total_secs
    );
}

#[test]
fn table2_optimal_beats_baselines_in_simulation() {
    // The Table II claim, checked in the simulator (not just the model):
    // the DSE's choice is at least as fast as both pure extensions.
    let hw = HardwareConfig::paper();
    let acc = paper_acc();
    for l in cnn::alexnet_layers() {
        let e = dse::explore(&hw, l.m, l.k, l.n, acc.surface()).unwrap();
        let opt = acc
            .simulate(&e.best.run, l.m, l.k, l.n, &SimOptions::default())
            .unwrap();
        for np in [4usize, 1] {
            let base = dse::baseline(&hw, np, l.m, l.k, l.n, acc.surface()).unwrap();
            let sim = acc
                .simulate(&base.run, l.m, l.k, l.n, &SimOptions::default())
                .unwrap();
            assert!(
                opt.gflops >= sim.gflops * 0.98,
                "{}: optimal {} ({:.1}) slower than np={} {} ({:.1})",
                l.name,
                e.best.run,
                opt.gflops,
                np,
                base.run,
                sim.gflops
            );
        }
    }
}

#[test]
fn fc6_reaches_high_efficiency() {
    // Paper: 100.9 / 102.4 GFLOPS = 98.6% on fc-6.
    let hw = HardwareConfig::paper();
    let acc = paper_acc();
    let l = cnn::layer("fc6").unwrap();
    let e = dse::explore(&hw, l.m, l.k, l.n, acc.surface()).unwrap();
    let sim = acc
        .simulate(&e.best.run, l.m, l.k, l.n, &SimOptions::default())
        .unwrap();
    assert!(
        sim.efficiency(&hw) > 0.9,
        "fc6 efficiency {:.3} below 0.9",
        sim.efficiency(&hw)
    );
}

#[test]
fn work_stealing_recovers_skewed_bandwidth() {
    let acc = paper_acc();
    let run = RunConfig::square(4, 64);
    let skew = Some(vec![1.0, 1.0, 0.5, 0.25]);
    let on = acc
        .simulate(
            &run,
            2048,
            512,
            2048,
            &SimOptions { stealing: true, bw_skew: skew.clone(), ..Default::default() },
        )
        .unwrap();
    let off = acc
        .simulate(&run, 2048, 512, 2048, &SimOptions { stealing: false, bw_skew: skew, ..Default::default() })
        .unwrap();
    assert!(on.total_steals > 0);
    assert!(
        on.total_secs < off.total_secs * 0.95,
        "stealing {} not faster than static {}",
        on.total_secs,
        off.total_secs
    );
}

#[test]
fn coordinator_end_to_end_golden() {
    let co = Coordinator::new(HardwareConfig::paper(), NumericsEngine::golden());
    let a = Matrix::random(200, 120, 10);
    let b = Matrix::random(120, 160, 11);
    let want = a.matmul(&b);
    let r = co.run_job(GemmJob { id: 1, a: a.into(), b: b.into(), run: None }).unwrap();
    assert!(r.c.allclose(&want, 1e-4));
    assert!(r.sim.gflops > 0.0);
    assert_eq!(co.metrics().jobs(), 1);
}

#[test]
fn coordinator_batch_of_jobs() {
    let co = Coordinator::new(HardwareConfig::paper(), NumericsEngine::golden());
    for (i, (m, k, n)) in [(64usize, 32usize, 64usize), (100, 50, 70), (33, 17, 9)]
        .iter()
        .enumerate()
    {
        let a = Matrix::random(*m, *k, i as u64);
        let b = Matrix::random(*k, *n, 100 + i as u64);
        let want = a.matmul(&b);
        let r = co
            .run_job(GemmJob { id: i as u64, a: a.into(), b: b.into(), run: None })
            .unwrap();
        assert!(r.c.allclose(&want, 1e-4), "job {i}");
    }
    assert_eq!(co.metrics().jobs(), 3);
}

#[test]
fn dse_agrees_with_exhaustive_simulation_ranking() {
    // The model's chosen optimum should land in the top tier of the
    // simulated ranking (the model is a predictor, not an oracle —
    // within 5% of the simulated best is a pass).
    let hw = HardwareConfig::paper();
    let acc = paper_acc();
    let l = cnn::layer("conv3").unwrap();
    let e = dse::explore(&hw, l.m, l.k, l.n, acc.surface()).unwrap();
    let chosen = acc
        .simulate(&e.best.run, l.m, l.k, l.n, &SimOptions::default())
        .unwrap();
    let mut best_sim = 0.0f64;
    for p in &e.points {
        let s = acc
            .simulate(&p.run, l.m, l.k, l.n, &SimOptions::default())
            .unwrap();
        best_sim = best_sim.max(s.gflops);
    }
    assert!(
        chosen.gflops >= 0.95 * best_sim,
        "DSE pick {:.1} vs simulated best {:.1}",
        chosen.gflops,
        best_sim
    );
}

#[test]
fn bandwidth_surface_matches_direct_measurement() {
    let hw = HardwareConfig::paper();
    let surface = BandwidthSurface::calibrate(&hw.ddr);
    let direct = multi_array::ddr::DdrSim::block_bandwidth(&hw.ddr, 2, 128);
    let cached = surface.bw(2, 128);
    assert!((cached - direct.per_master).abs() / direct.per_master < 1e-9);
}
