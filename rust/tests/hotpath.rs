//! Hot-path integration tests: the packed panel pipeline against the
//! oracle across awkward shapes, the lock-free WQM under real thread
//! contention, and the coordinator's zero-copy guarantee.

use multi_array::blocking::BlockPlan;
use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::coordinator::{Coordinator, GemmJob, NumericsEngine};
use multi_array::gemm::{self, DisjointBlocks, Matrix, PackedPanels};
use multi_array::util::check;
use multi_array::wqm::AtomicWqm;

#[test]
fn packed_matmul_matches_oracle_on_awkward_shapes() {
    // Primes and off-by-one sizes so every strip/block edge case fires.
    for (m, k, n, si, sj) in [
        (1, 1, 1, 1, 1),
        (3, 5, 2, 4, 8),
        (4, 8, 8, 4, 8),
        (31, 37, 29, 16, 16),
        (64, 64, 64, 16, 16),
        (65, 127, 63, 32, 24),
        (97, 13, 101, 40, 7),
        (128, 256, 128, 128, 128),
    ] {
        let a = Matrix::random(m, k, (m * 1000 + n) as u64);
        let b = Matrix::random(k, n, (n * 1000 + k) as u64);
        let got = gemm::packed_matmul(&a, &b, si, sj);
        let want = a.matmul(&b);
        assert!(
            got.allclose(&want, 1e-3),
            "{m}x{k}x{n} si={si} sj={sj}: max err {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn prop_packed_pipeline_vs_oracle() {
    check::cases(48, |rng| {
        let (m, k, n) = (rng.range(1, 60), rng.range(1, 60), rng.range(1, 60));
        let (si, sj) = (rng.range(1, 32), rng.range(1, 32));
        let seed = rng.next_u64();
        let a = Matrix::random(m, k, seed);
        let b = Matrix::random(k, n, seed + 1);
        let got = gemm::packed_matmul(&a, &b, si, sj);
        assert!(got.allclose(&a.matmul(&b), 1e-3));
    });
}

#[test]
fn packed_edge_blocks_match_scalar_reference() {
    // The last block row/column is ragged in both dimensions; the packed
    // and scalar paths must agree block by block, not just in aggregate.
    let a = Matrix::random(70, 23, 1);
    let b = Matrix::random(23, 50, 2);
    let plan = BlockPlan::new(70, 23, 50, 32, 32);
    let panels = PackedPanels::pack(a.view(), b.view(), &plan);
    for task in plan.tasks() {
        let packed = gemm::task_product(&panels, &task);
        let scalar = gemm::block_task(&a, &b, task.row0, task.col0, task.si, task.sj);
        assert_eq!((packed.rows, packed.cols), (scalar.rows, scalar.cols));
        assert!(packed.allclose(&scalar, 1e-5), "task {}", task.id);
    }
}

#[test]
fn packed_writer_assembles_c_through_disjoint_blocks() {
    // Drive the writer across threads exactly as the coordinator does,
    // but directly (no engine), to pin the disjoint-write contract.
    let a = Matrix::random(96, 48, 3);
    let b = Matrix::random(48, 80, 4);
    let plan = BlockPlan::new(96, 48, 80, 16, 16);
    let panels = PackedPanels::pack(a.view(), b.view(), &plan);
    let wqm = AtomicWqm::from_partition(plan.partition(4));
    let mut c = Matrix::zeros(96, 80);
    {
        let writer = DisjointBlocks::new(c.view_mut());
        std::thread::scope(|s| {
            for w in 0..4 {
                let wqm = &wqm;
                let writer = &writer;
                let panels = &panels;
                s.spawn(move || {
                    while let Some(task) = wqm.pop(w) {
                        // SAFETY: the WQM hands each task to exactly one
                        // thread and tasks tile C disjointly.
                        unsafe { gemm::task_product_into(panels, &task, writer) };
                    }
                });
            }
        });
    }
    assert!(c.allclose(&a.matmul(&b), 1e-4));
    assert_eq!(
        wqm.stats().iter().map(|s| s.executed).sum::<u64>(),
        plan.num_tasks() as u64
    );
}

#[test]
fn atomic_wqm_threaded_conservation_over_block_tasks() {
    // 1024 real BlockTasks, 8 threads, stealing on: every task id
    // claimed exactly once, steal counters balance.
    let plan = BlockPlan::new(2048, 16, 2048, 64, 64);
    let wqm = AtomicWqm::from_partition(plan.partition(4));
    let mut ids: Vec<usize> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..8 {
            let wqm = &wqm;
            handles.push(s.spawn(move || {
                let mut mine = Vec::new();
                let mut q = t % 4;
                while let Some(task) = wqm.pop(q) {
                    mine.push(task.id);
                    q = (q + 1) % 4;
                }
                mine
            }));
        }
        for h in handles {
            ids.extend(h.join().unwrap());
        }
    });
    ids.sort_unstable();
    assert_eq!(ids, (0..plan.num_tasks()).collect::<Vec<_>>());
    let stats = wqm.stats();
    assert_eq!(
        stats.iter().map(|s| s.stolen_in).sum::<u64>(),
        stats.iter().map(|s| s.stolen_out).sum::<u64>()
    );
}

#[test]
fn coordinator_zero_copy_and_correct_across_partitions() {
    // np > tasks, np == tasks, np < tasks — all correct, none copying
    // panels on the golden path.
    let co = Coordinator::new(HardwareConfig::paper(), NumericsEngine::golden());
    for (m, k, n, np, si) in [
        (10usize, 8usize, 12usize, 4usize, 16usize), // 1 task, 4 workers
        (30, 20, 30, 2, 16),                         // 4 tasks, 2 workers
        (130, 40, 130, 4, 32),                       // 25 tasks, 4 workers
    ] {
        let a = Matrix::random(m, k, (m + n) as u64);
        let b = Matrix::random(k, n, (m * n) as u64);
        let want = a.matmul(&b);
        let job = GemmJob { id: 0, a: a.into(), b: b.into(), run: Some(RunConfig::square(np, si)) };
        let r = co.run_job(job).unwrap();
        assert!(r.c.allclose(&want, 1e-4), "{m}x{k}x{n} np={np}");
    }
    assert_eq!(co.metrics().panel_copies(), 0);
    assert_eq!(co.metrics().jobs(), 3);
}

#[test]
fn transpose_feeds_packer_consistently() {
    // The cache-blocked transpose and the packer's transposed A layout
    // describe the same data: packing A equals packing from A^T^T.
    let a = Matrix::random(67, 45, 9);
    let tt = a.transpose().transpose();
    assert_eq!(a, tt);
    let b = Matrix::random(45, 33, 10);
    let plan = BlockPlan::new(67, 45, 33, 16, 16);
    let p1 = PackedPanels::pack(a.view(), b.view(), &plan);
    let p2 = PackedPanels::pack(tt.view(), b.view(), &plan);
    for bi in 0..plan.blocks_i() {
        assert_eq!(p1.a_panel(bi).0, p2.a_panel(bi).0);
    }
}
