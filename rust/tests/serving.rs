//! Serving-runtime integration tests: the multi-job `JobServer` under
//! real thread contention — per-job correctness against the scalar
//! reference, task conservation across the job table, cross-job
//! stealing actually firing, batching bit-identity, and backpressure.

use multi_array::blocking::BlockPlan;
use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::coordinator::{
    Coordinator, GemmJob, JobServer, NumericsEngine, ServerConfig, TrySubmitBatchedError,
    TrySubmitError,
};
use multi_array::gemm::Matrix;

fn server(cfg: ServerConfig) -> JobServer {
    JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), cfg).unwrap()
}

fn cfg(workers: usize, capacity: usize) -> ServerConfig {
    ServerConfig {
        workers,
        queue_capacity: capacity,
        batch_max_tasks: 4,
        batch_window: 4,
        cross_job_stealing: true,
        default_run: None,
        ..ServerConfig::default()
    }
}

/// Expected WQM task count of a job pinned to `run`.
fn tasks_of(m: usize, k: usize, n: usize, run: RunConfig) -> usize {
    BlockPlan::new(m, k, n, run.si, run.sj).num_tasks()
}

#[test]
fn stress_concurrent_mixed_size_submitters() {
    // Several client threads submit mixed-size jobs concurrently; every
    // result matches the scalar reference, and the task count across
    // the whole job table is conserved exactly.
    let srv = server(cfg(4, 16));
    let run = RunConfig::square(2, 16);
    let mut expected_tasks = 0usize;
    let threads = 4usize;
    let per_thread = 8usize;
    for t in 0..threads {
        for i in 0..per_thread {
            let (m, k, n) = shape(t, i);
            expected_tasks += tasks_of(m, k, n, run);
        }
    }
    std::thread::scope(|s| {
        for t in 0..threads {
            let srv = &srv;
            s.spawn(move || {
                for i in 0..per_thread {
                    let (m, k, n) = shape(t, i);
                    let seed = (t * 1000 + i) as u64;
                    let a = Matrix::random(m, k, seed);
                    let b = Matrix::random(k, n, seed + 500);
                    let want = a.matmul(&b);
                    let ticket = srv
                        .submit(GemmJob { id: seed, a: a.into(), b: b.into(), run: Some(run) })
                        .unwrap();
                    let r = ticket.wait().unwrap();
                    assert_eq!(r.id, seed);
                    assert!(
                        r.c.allclose(&want, 1e-4),
                        "job {seed} ({m}x{k}x{n}) wrong"
                    );
                }
            });
        }
    });
    let m = srv.metrics();
    assert_eq!(m.jobs(), (threads * per_thread) as u64);
    assert_eq!(m.jobs_failed(), 0);
    assert_eq!(m.tasks(), expected_tasks as u64, "task conservation across the job table");
    // Golden in-process engine: the packed zero-copy path, no gathers.
    assert_eq!(m.panel_copies(), 0);
}

fn shape(t: usize, i: usize) -> (usize, usize, usize) {
    // Mixed sizes: from single-task 16x8x16 up to 64x20x48.
    (16 * (1 + (t + i) % 4), 8 + 4 * t, 16 * (1 + i % 3))
}

#[test]
fn sixty_four_concurrent_mixed_jobs_with_cross_job_stealing() {
    // The acceptance-scale run: >= 64 concurrent mixed-size jobs through
    // one pool, elephants and mice together. All correct, tasks
    // conserved, and the pool demonstrably stole across jobs.
    let srv = server(cfg(4, 64));
    let run = RunConfig::square(4, 16);
    let njobs = 64usize;
    let mut pending = Vec::with_capacity(njobs);
    let mut expected_tasks = 0usize;
    for j in 0..njobs {
        // Every 8th job is an elephant; the rest are small.
        let (m, k, n) = if j % 8 == 0 { (160, 48, 160) } else { (16 + 8 * (j % 3), 12, 24) };
        expected_tasks += tasks_of(m, k, n, run);
        let seed = j as u64;
        let a = Matrix::random(m, k, seed);
        let b = Matrix::random(k, n, seed + 1000);
        let want = a.matmul(&b);
        let ticket = srv
            .submit(GemmJob { id: seed, a: a.into(), b: b.into(), run: Some(run) })
            .unwrap();
        pending.push((ticket, want));
    }
    for (ticket, want) in pending {
        let r = ticket.wait().unwrap();
        assert!(r.c.allclose(&want, 1e-4), "job {} wrong", r.id);
    }
    let m = srv.metrics();
    assert_eq!(m.jobs(), njobs as u64);
    assert_eq!(m.tasks(), expected_tasks as u64);
    // All 64 jobs are admitted before any ticket is waited on, so many
    // are live concurrently (8 elephants of 100 tasks each guarantee
    // long-lived jobs). A switch is counted whenever a worker leaves a
    // still-live job for another — which happens every time a worker
    // drains its job's queues while a sibling still holds one of its
    // tasks in flight, a window this mix opens dozens of times. For the
    // counter to stay 0, every such window across the whole run would
    // have to be missed by every worker (each miss needs the OS to park
    // the worker for an entire task execution) — not a real schedule.
    assert!(m.cross_job_steals() > 0, "no cross-job steals recorded");
    let stats = srv.stats();
    assert!(stats.latency_p95_secs >= stats.latency_p50_secs);
    assert!((0.0..=1.0).contains(&stats.worker_idle_frac));
}

#[test]
fn batched_small_jobs_bit_identical_to_individual_runs() {
    // The same small GEMMs through (a) a batched super-job on the server
    // and (b) individual Coordinator::run_job calls must produce
    // bit-identical C matrices: same packing, same microkernel, same
    // per-element accumulation order.
    let run = RunConfig::square(2, 16);
    let jobs: Vec<(Matrix, Matrix)> = (0..8u64)
        .map(|i| {
            (
                Matrix::random(24, 16, 7000 + i),
                Matrix::random(16, 32, 8000 + i),
            )
        })
        .collect();

    let srv = server(ServerConfig { batch_window: 8, ..cfg(4, 16) });
    let tickets = srv
        .submit_batch(
            jobs.iter()
                .enumerate()
                .map(|(i, (a, b))| GemmJob {
                    id: i as u64,
                    a: a.clone().into(),
                    b: b.clone().into(),
                    run: Some(run),
                })
                .collect(),
        )
        .unwrap();
    let served: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    assert!(served.iter().all(|r| r.batched), "small group members must batch");
    assert!(srv.metrics().batched_jobs() >= 8);

    let co = Coordinator::new(HardwareConfig::paper(), NumericsEngine::golden());
    for (r, (a, b)) in served.iter().zip(&jobs) {
        let individual = co
            .run_job(GemmJob {
                id: r.id,
                a: a.clone().into(),
                b: b.clone().into(),
                run: Some(run),
            })
            .unwrap();
        assert!(!individual.batched);
        assert_eq!(
            r.c.data, individual.c.data,
            "batched job {} not bit-identical to its individual run",
            r.id
        );
    }
}

#[test]
fn batched_gemm_bit_identical_across_ragged_shapes() {
    // The acceptance gate for the shared-operand pipeline: for ragged
    // prime/odd shapes, `submit_batched_gemm` must produce bit-identical
    // results to N individual `submit` calls — same packed layout, same
    // microkernel, same per-element ascending-k accumulation, shared or
    // not. (M, K, N) triples deliberately hit every edge: rows % MR,
    // cols % NR, blocks clipping at both matrix edges.
    let run = RunConfig::square(2, 16);
    for (k, n, ms, seed) in [
        (13usize, 29usize, vec![7usize, 31, 1, 17], 600u64),
        (23, 17, vec![19, 3, 41], 700),
        (5, 53, vec![37, 11, 13, 9, 2], 800),
    ] {
        let b = Matrix::random(k, n, seed);
        let many_a: Vec<Matrix> = ms
            .iter()
            .enumerate()
            .map(|(i, &m)| Matrix::random(m, k, seed + 1 + i as u64))
            .collect();

        // Individual submissions on their own server.
        let individual = server(cfg(4, 16));
        let singles: Vec<Matrix> = many_a
            .iter()
            .enumerate()
            .map(|(i, a)| {
                individual
                    .submit(GemmJob {
                        id: i as u64,
                        a: a.clone().into(),
                        b: b.clone().into(),
                        run: Some(run),
                    })
                    .unwrap()
                    .wait()
                    .unwrap()
                    .c
            })
            .collect();

        // The same jobs as one shared-B batch.
        let batched = server(cfg(4, 16));
        let results = batched
            .submit_batched_gemm(b.clone(), many_a.clone(), Some(run))
            .unwrap()
            .wait_all()
            .unwrap();
        assert_eq!(results.len(), singles.len());
        for ((i, r), (single, a)) in
            results.iter().enumerate().zip(singles.iter().zip(&many_a))
        {
            assert_eq!(r.id, i as u64, "results in many_a order");
            assert_eq!(
                r.c.data, single.data,
                "shared-B result {i} ({}x{k}x{n}) not bit-identical",
                a.rows
            );
            // And both agree with the oracle (not just with each other).
            assert!(r.c.allclose(&a.matmul(&b), 1e-4));
        }
    }
}

#[test]
fn batched_gemm_conserves_one_b_pack() {
    // Pack conservation, metrics-asserted: N sub-jobs against one B
    // perform exactly one B pack and N A packs; the N-1 avoided packs
    // are recorded as panels_shared; individual submission of the same
    // workload pays N B packs.
    let run = RunConfig::square(2, 16);
    let n_jobs = 6u64;
    let b = Matrix::random(19, 27, 1000);
    let many_a: Vec<Matrix> =
        (0..n_jobs).map(|i| Matrix::random(21, 19, 1001 + i)).collect();

    let batched = server(cfg(4, 16));
    batched
        .submit_batched_gemm(b.clone(), many_a.clone(), Some(run))
        .unwrap()
        .wait_all()
        .unwrap();
    let m = batched.metrics();
    assert_eq!(m.b_panel_packs(), 1, "shared B must be packed exactly once");
    assert_eq!(m.a_panel_packs(), n_jobs);
    assert_eq!(m.panels_shared(), n_jobs - 1);
    assert_eq!(m.panel_copies(), 0, "no per-task gathers on the golden path");
    let stats = batched.stats();
    assert_eq!(stats.b_panel_packs, 1);
    assert_eq!(stats.panels_shared, n_jobs - 1);
    assert_eq!(stats.shared_b_groups, 1);
    assert_eq!(stats.batched_jobs, n_jobs);

    // Baseline: the same traffic submitted individually packs B per job.
    let individual = server(cfg(4, 16));
    for (i, a) in many_a.into_iter().enumerate() {
        individual
            .submit(GemmJob { id: i as u64, a: a.into(), b: b.clone().into(), run: Some(run) })
            .unwrap()
            .wait()
            .unwrap();
    }
    assert_eq!(individual.metrics().b_panel_packs(), n_jobs);
    assert_eq!(individual.metrics().panels_shared(), 0);
}

#[test]
fn registered_b_bit_identical_to_inline_across_ragged_shapes() {
    // The registry acceptance gate: submissions through a registered
    // WeightHandle must be bit-identical to inline submissions — the
    // cached pack IS the pack an inline call would build, for ragged
    // prime/odd shapes hitting every packing edge, on the batched and
    // the lone-job path alike, across repeated cache-hitting calls.
    let run = RunConfig::square(2, 16);
    for (k, n, ms, seed) in [
        (13usize, 29usize, vec![7usize, 31, 1, 17], 2600u64),
        (23, 17, vec![19, 3, 41], 2700),
        (5, 53, vec![37, 11, 13, 9, 2], 2800),
    ] {
        let b = Matrix::random(k, n, seed);
        let many_a: Vec<Matrix> = ms
            .iter()
            .enumerate()
            .map(|(i, &m)| Matrix::random(m, k, seed + 1 + i as u64))
            .collect();

        // Inline shared batch on its own server — the baseline bits.
        let inline = server(cfg(4, 16));
        let inline_results = inline
            .submit_batched_gemm(b.clone(), many_a.clone(), Some(run))
            .unwrap()
            .wait_all()
            .unwrap();

        // The same batch through a registered handle, twice: the
        // second call resolves from cache and must not perturb a bit.
        let registered = server(cfg(4, 16));
        let h = registered.register_b(b.clone()).unwrap();
        for call in 0..2 {
            let results = registered
                .submit_batched_gemm(h, many_a.clone(), Some(run))
                .unwrap()
                .wait_all()
                .unwrap();
            for ((r, want), a) in results.iter().zip(&inline_results).zip(&many_a) {
                assert_eq!(
                    r.c.data, want.c.data,
                    "call {call}: registered result for {}x{k}x{n} diverged",
                    a.rows
                );
            }
        }
        assert_eq!(registered.metrics().b_panel_packs(), 1);
        assert_eq!(registered.metrics().registry_hits(), 1);
        // Lone registered submits reuse the same cached pack and agree.
        for (i, (a, want)) in many_a.iter().zip(&inline_results).enumerate() {
            let r = registered
                .submit(GemmJob { id: i as u64, a: a.clone().into(), b: h.into(), run: Some(run) })
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(r.c.data, want.c.data);
            // And both agree with the oracle (not just with each other).
            assert!(r.c.allclose(&a.matmul(&b), 1e-4));
        }
        assert_eq!(
            registered.metrics().b_panel_packs(),
            1,
            "lone submits reuse the same cached pack"
        );
    }
}

#[test]
fn registered_calls_conserve_one_pack_vs_inline_baseline() {
    // Pack conservation across CALLS, metrics-asserted: four successive
    // batched calls under one handle perform exactly one B pack; the
    // same four calls with an inline B pack four times.
    let run = Some(RunConfig::square(2, 16));
    let b = Matrix::random(19, 27, 5000);
    let calls = 4u64;

    let registered = server(cfg(4, 16));
    let h = registered.register_b(b.clone()).unwrap();
    for call in 0..calls {
        let many_a: Vec<Matrix> =
            (0..3u64).map(|i| Matrix::random(21, 19, 5001 + 10 * call + i)).collect();
        let wants: Vec<Matrix> = many_a.iter().map(|a| a.matmul(&b)).collect();
        let results =
            registered.submit_batched_gemm(h, many_a, run).unwrap().wait_all().unwrap();
        for (r, want) in results.iter().zip(&wants) {
            assert!(r.c.allclose(want, 1e-4));
        }
    }
    let m = registered.metrics();
    assert_eq!(m.b_panel_packs(), 1, "one pack across all four calls");
    assert_eq!(m.registry_misses(), 1);
    assert_eq!(m.registry_hits(), calls - 1);

    // Inline baseline: the identical traffic repacks per call.
    let inline = server(cfg(4, 16));
    for call in 0..calls {
        let many_a: Vec<Matrix> =
            (0..3u64).map(|i| Matrix::random(21, 19, 5001 + 10 * call + i)).collect();
        inline.submit_batched_gemm(b.clone(), many_a, run).unwrap().wait_all().unwrap();
    }
    assert_eq!(inline.metrics().b_panel_packs(), calls, "inline packs once per call");
    assert_eq!(inline.metrics().registry_hits(), 0);
}

#[test]
fn registry_eviction_under_tight_budget_keeps_results_correct() {
    // A 1-byte budget forces every resolution over budget: unpinned
    // packs evict, handles stay valid (evicted packs transparently
    // repack), and results stay correct throughout.
    let mut c = cfg(4, 16);
    c.registry_budget_bytes = 1;
    let srv = server(c);
    let b1 = Matrix::random(16, 24, 3100);
    let b2 = Matrix::random(16, 24, 3101);
    let h1 = srv.register_b(b1.clone()).unwrap();
    let h2 = srv.register_b(b2.clone()).unwrap();
    let run = Some(RunConfig::square(2, 16));
    for round in 0..3u64 {
        for (j, (h, b)) in [(h1, &b1), (h2, &b2)].into_iter().enumerate() {
            let a = Matrix::random(20, 16, 3200 + 10 * round + j as u64);
            let want = a.matmul(b);
            let r = srv
                .submit(GemmJob { id: round, a: a.into(), b: h.into(), run })
                .unwrap()
                .wait()
                .unwrap();
            assert!(r.c.allclose(&want, 1e-4), "round {round} handle {j}");
        }
    }
    assert_eq!(srv.metrics().jobs(), 6);
    // Deterministic pressure on the registry surface itself: a fresh
    // block-size variant is a guaranteed miss, and once its Arc is
    // dropped it is unpinned — the next insert must evict it (and any
    // other unpinned pack) to chase the 1-byte budget.
    let reg = srv.operand_registry();
    drop(reg.resolve_pack(h1, 8).unwrap());
    let before = srv.metrics().registry_evictions();
    drop(reg.resolve_pack(h2, 8).unwrap());
    assert!(
        srv.metrics().registry_evictions() > before,
        "unpinned LRU pack must evict under a 1-byte budget"
    );
    // Both weights survived every eviction (packs evict, matrices stay).
    assert_eq!(srv.stats().registered_weights, 2);
}

#[test]
fn try_submit_batched_gemm_sheds_with_operands_returned() {
    // The load-shedding contract extended to shared-B groups: a shed
    // batch hands every operand back intact; an admitted batch must
    // complete correctly. Nothing is ever silently dropped.
    let srv = server(cfg(2, 2));
    let run = Some(RunConfig::square(2, 16));
    let b = Matrix::random(16, 32, 4000);
    let mut admitted = Vec::new();
    let mut shed = 0usize;
    let batches = 60u64;
    for j in 0..batches {
        let many_a: Vec<Matrix> =
            (0..2u64).map(|i| Matrix::random(24, 16, 4100 + 10 * j + i)).collect();
        let wants: Vec<Matrix> = many_a.iter().map(|a| a.matmul(&b)).collect();
        match srv.try_submit_batched_gemm(b.clone(), many_a, run) {
            Ok(group) => admitted.push((group, wants)),
            Err(TrySubmitBatchedError::Full { b: back, many_a }) => {
                assert_eq!(back.inline_dims(), Some((16, 32)), "B must come back intact");
                assert_eq!(many_a.len(), 2);
                assert!(many_a.iter().all(|a| (a.rows, a.cols) == (24, 16)));
                shed += 1;
            }
            Err(e) => panic!("unexpected rejection: {e:?}"),
        }
    }
    assert!(!admitted.is_empty());
    let mut completed = 0usize;
    for (group, wants) in admitted {
        for (r, want) in group.wait_all().unwrap().iter().zip(&wants) {
            assert!(r.c.allclose(want, 1e-4));
            completed += 1;
        }
    }
    assert_eq!(srv.metrics().jobs() as usize, completed);
    assert_eq!(completed / 2 + shed, batches as usize, "admitted + shed covers every batch");
}

#[test]
fn try_submit_sheds_load_without_losing_jobs() {
    // try_submit either admits a job (which must then complete
    // correctly) or hands it back intact — never silently drops it.
    let srv = server(cfg(2, 2));
    let run = RunConfig::square(2, 16);
    let mut admitted = Vec::new();
    let mut rejected = 0usize;
    for j in 0..100u64 {
        let a = Matrix::random(32, 16, j);
        let b = Matrix::random(16, 32, j + 200);
        let want = a.matmul(&b);
        match srv.try_submit(GemmJob { id: j, a: a.into(), b: b.into(), run: Some(run) }) {
            Ok(t) => admitted.push((t, want)),
            Err(TrySubmitError::Full(job)) => {
                assert_eq!(job.id, j, "rejected job must come back intact");
                assert_eq!(job.a.inline_dims(), Some((32, 16)));
                assert_eq!(job.b.as_inline().unwrap().cols, 32);
                rejected += 1;
            }
            Err(TrySubmitError::Closed(_)) => panic!("server is not closed"),
        }
    }
    assert!(!admitted.is_empty());
    for (t, want) in admitted {
        assert!(t.wait().unwrap().c.allclose(&want, 1e-4));
    }
    // Conservation: admitted + rejected covers every submission.
    assert_eq!(srv.metrics().jobs() as usize + rejected, 100);
}

#[test]
fn steals_balance_and_zero_copy_hold_under_serving() {
    // Aggregated WQM statistics stay coherent when many jobs flow
    // through the shared pool, and the golden path stays zero-copy.
    let srv = server(cfg(4, 32));
    let run = RunConfig::square(4, 16);
    let mut pending = Vec::new();
    for j in 0..24u64 {
        let a = Matrix::random(64, 24, j);
        let b = Matrix::random(24, 64, j + 77);
        let want = a.matmul(&b);
        pending.push((
            srv.submit(GemmJob { id: j, a: a.into(), b: b.into(), run: Some(run) }).unwrap(),
            want,
        ));
    }
    for (t, want) in pending {
        assert!(t.wait().unwrap().c.allclose(&want, 1e-4));
    }
    let m = srv.metrics();
    assert_eq!(m.panel_copies(), 0);
    // Intra-job steals are bounded by total tasks; cross-job steals are
    // bounded by total pops (sanity, not exact accounting).
    assert!(m.steals() <= m.tasks());
    assert!(m.cross_job_steals() <= m.tasks());
    srv.shutdown();
}
