//! Serving-runtime integration tests: the multi-job `JobServer` under
//! real thread contention — per-job correctness against the scalar
//! reference, task conservation across the job table, cross-job
//! stealing actually firing, batching bit-identity, backpressure, and
//! the multi-tenant admission front end (DRR fairness, quota hand-back,
//! deadline accounting, async/blocking bit-identity).
//!
//! Several tests deliberately exercise the deprecated pre-`Submission`
//! entry points (`submit`, `submit_batch`, `submit_batched_gemm`, ...):
//! they are kept shims and must keep behaving until removed.
#![allow(deprecated)]

use std::time::Duration;

use multi_array::blocking::BlockPlan;
use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::coordinator::{
    Coordinator, GemmJob, JobServer, NumericsEngine, ServerConfig, SubmitError, Submission,
    SubmissionKind, TenantConfig, TenantId, Terminal, TrySubmitBatchedError,
};
use multi_array::gemm::Matrix;

fn server(cfg: ServerConfig) -> JobServer {
    JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), cfg).unwrap()
}

fn cfg(workers: usize, capacity: usize) -> ServerConfig {
    ServerConfig {
        workers,
        queue_capacity: capacity,
        batch_max_tasks: 4,
        batch_window: 4,
        cross_job_stealing: true,
        default_run: None,
        ..ServerConfig::default()
    }
}

/// Expected WQM task count of a job pinned to `run`.
fn tasks_of(m: usize, k: usize, n: usize, run: RunConfig) -> usize {
    BlockPlan::new(m, k, n, run.si, run.sj).num_tasks()
}

#[test]
fn stress_concurrent_mixed_size_submitters() {
    // Several client threads submit mixed-size jobs concurrently; every
    // result matches the scalar reference, and the task count across
    // the whole job table is conserved exactly.
    let srv = server(cfg(4, 16));
    let run = RunConfig::square(2, 16);
    let mut expected_tasks = 0usize;
    let threads = 4usize;
    let per_thread = 8usize;
    for t in 0..threads {
        for i in 0..per_thread {
            let (m, k, n) = shape(t, i);
            expected_tasks += tasks_of(m, k, n, run);
        }
    }
    std::thread::scope(|s| {
        for t in 0..threads {
            let srv = &srv;
            s.spawn(move || {
                for i in 0..per_thread {
                    let (m, k, n) = shape(t, i);
                    let seed = (t * 1000 + i) as u64;
                    let a = Matrix::random(m, k, seed);
                    let b = Matrix::random(k, n, seed + 500);
                    let want = a.matmul(&b);
                    let ticket = srv
                        .submit(GemmJob { id: seed, a: a.into(), b: b.into(), run: Some(run) })
                        .unwrap();
                    let r = ticket.wait().unwrap();
                    assert_eq!(r.id, seed);
                    assert!(
                        r.c.allclose(&want, 1e-4),
                        "job {seed} ({m}x{k}x{n}) wrong"
                    );
                }
            });
        }
    });
    let m = srv.metrics();
    assert_eq!(m.jobs(), (threads * per_thread) as u64);
    assert_eq!(m.jobs_failed(), 0);
    assert_eq!(m.tasks(), expected_tasks as u64, "task conservation across the job table");
    // Golden in-process engine: the packed zero-copy path, no gathers.
    assert_eq!(m.panel_copies(), 0);
}

fn shape(t: usize, i: usize) -> (usize, usize, usize) {
    // Mixed sizes: from single-task 16x8x16 up to 64x20x48.
    (16 * (1 + (t + i) % 4), 8 + 4 * t, 16 * (1 + i % 3))
}

#[test]
fn sixty_four_concurrent_mixed_jobs_with_cross_job_stealing() {
    // The acceptance-scale run: >= 64 concurrent mixed-size jobs through
    // one pool, elephants and mice together. All correct, tasks
    // conserved, and the pool demonstrably stole across jobs.
    let srv = server(cfg(4, 64));
    let run = RunConfig::square(4, 16);
    let njobs = 64usize;
    let mut pending = Vec::with_capacity(njobs);
    let mut expected_tasks = 0usize;
    for j in 0..njobs {
        // Every 8th job is an elephant; the rest are small.
        let (m, k, n) = if j % 8 == 0 { (160, 48, 160) } else { (16 + 8 * (j % 3), 12, 24) };
        expected_tasks += tasks_of(m, k, n, run);
        let seed = j as u64;
        let a = Matrix::random(m, k, seed);
        let b = Matrix::random(k, n, seed + 1000);
        let want = a.matmul(&b);
        let ticket = srv
            .submit(GemmJob { id: seed, a: a.into(), b: b.into(), run: Some(run) })
            .unwrap();
        pending.push((ticket, want));
    }
    for (ticket, want) in pending {
        let r = ticket.wait().unwrap();
        assert!(r.c.allclose(&want, 1e-4), "job {} wrong", r.id);
    }
    let m = srv.metrics();
    assert_eq!(m.jobs(), njobs as u64);
    assert_eq!(m.tasks(), expected_tasks as u64);
    // All 64 jobs are admitted before any ticket is waited on, so many
    // are live concurrently (8 elephants of 100 tasks each guarantee
    // long-lived jobs). A switch is counted whenever a worker leaves a
    // still-live job for another — which happens every time a worker
    // drains its job's queues while a sibling still holds one of its
    // tasks in flight, a window this mix opens dozens of times. For the
    // counter to stay 0, every such window across the whole run would
    // have to be missed by every worker (each miss needs the OS to park
    // the worker for an entire task execution) — not a real schedule.
    assert!(m.cross_job_steals() > 0, "no cross-job steals recorded");
    let stats = srv.stats();
    assert!(stats.latency_p95_secs >= stats.latency_p50_secs);
    assert!((0.0..=1.0).contains(&stats.worker_idle_frac));
}

#[test]
fn batched_small_jobs_bit_identical_to_individual_runs() {
    // The same small GEMMs through (a) a batched super-job on the server
    // and (b) individual Coordinator::run_job calls must produce
    // bit-identical C matrices: same packing, same microkernel, same
    // per-element accumulation order.
    let run = RunConfig::square(2, 16);
    let jobs: Vec<(Matrix, Matrix)> = (0..8u64)
        .map(|i| {
            (
                Matrix::random(24, 16, 7000 + i),
                Matrix::random(16, 32, 8000 + i),
            )
        })
        .collect();

    let srv = server(ServerConfig { batch_window: 8, ..cfg(4, 16) });
    let tickets = srv
        .submit_batch(
            jobs.iter()
                .enumerate()
                .map(|(i, (a, b))| GemmJob {
                    id: i as u64,
                    a: a.clone().into(),
                    b: b.clone().into(),
                    run: Some(run),
                })
                .collect(),
        )
        .unwrap();
    let served: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    assert!(served.iter().all(|r| r.batched), "small group members must batch");
    assert!(srv.metrics().batched_jobs() >= 8);

    let co = Coordinator::new(HardwareConfig::paper(), NumericsEngine::golden());
    for (r, (a, b)) in served.iter().zip(&jobs) {
        let individual = co
            .run_job(GemmJob {
                id: r.id,
                a: a.clone().into(),
                b: b.clone().into(),
                run: Some(run),
            })
            .unwrap();
        assert!(!individual.batched);
        assert_eq!(
            r.c.data, individual.c.data,
            "batched job {} not bit-identical to its individual run",
            r.id
        );
    }
}

#[test]
fn batched_gemm_bit_identical_across_ragged_shapes() {
    // The acceptance gate for the shared-operand pipeline: for ragged
    // prime/odd shapes, `submit_batched_gemm` must produce bit-identical
    // results to N individual `submit` calls — same packed layout, same
    // microkernel, same per-element ascending-k accumulation, shared or
    // not. (M, K, N) triples deliberately hit every edge: rows % MR,
    // cols % NR, blocks clipping at both matrix edges.
    let run = RunConfig::square(2, 16);
    for (k, n, ms, seed) in [
        (13usize, 29usize, vec![7usize, 31, 1, 17], 600u64),
        (23, 17, vec![19, 3, 41], 700),
        (5, 53, vec![37, 11, 13, 9, 2], 800),
    ] {
        let b = Matrix::random(k, n, seed);
        let many_a: Vec<Matrix> = ms
            .iter()
            .enumerate()
            .map(|(i, &m)| Matrix::random(m, k, seed + 1 + i as u64))
            .collect();

        // Individual submissions on their own server.
        let individual = server(cfg(4, 16));
        let singles: Vec<Matrix> = many_a
            .iter()
            .enumerate()
            .map(|(i, a)| {
                individual
                    .submit(GemmJob {
                        id: i as u64,
                        a: a.clone().into(),
                        b: b.clone().into(),
                        run: Some(run),
                    })
                    .unwrap()
                    .wait()
                    .unwrap()
                    .c
            })
            .collect();

        // The same jobs as one shared-B batch.
        let batched = server(cfg(4, 16));
        let results = batched
            .submit_batched_gemm(b.clone(), many_a.clone(), Some(run))
            .unwrap()
            .wait_all()
            .unwrap();
        assert_eq!(results.len(), singles.len());
        for ((i, r), (single, a)) in
            results.iter().enumerate().zip(singles.iter().zip(&many_a))
        {
            assert_eq!(r.id, i as u64, "results in many_a order");
            assert_eq!(
                r.c.data, single.data,
                "shared-B result {i} ({}x{k}x{n}) not bit-identical",
                a.rows
            );
            // And both agree with the oracle (not just with each other).
            assert!(r.c.allclose(&a.matmul(&b), 1e-4));
        }
    }
}

#[test]
fn batched_gemm_conserves_one_b_pack() {
    // Pack conservation, metrics-asserted: N sub-jobs against one B
    // perform exactly one B pack and N A packs; the N-1 avoided packs
    // are recorded as panels_shared; individual submission of the same
    // workload pays N B packs.
    let run = RunConfig::square(2, 16);
    let n_jobs = 6u64;
    let b = Matrix::random(19, 27, 1000);
    let many_a: Vec<Matrix> =
        (0..n_jobs).map(|i| Matrix::random(21, 19, 1001 + i)).collect();

    let batched = server(cfg(4, 16));
    batched
        .submit_batched_gemm(b.clone(), many_a.clone(), Some(run))
        .unwrap()
        .wait_all()
        .unwrap();
    let m = batched.metrics();
    assert_eq!(m.b_panel_packs(), 1, "shared B must be packed exactly once");
    assert_eq!(m.a_panel_packs(), n_jobs);
    assert_eq!(m.panels_shared(), n_jobs - 1);
    assert_eq!(m.panel_copies(), 0, "no per-task gathers on the golden path");
    let stats = batched.stats();
    assert_eq!(stats.b_panel_packs, 1);
    assert_eq!(stats.panels_shared, n_jobs - 1);
    assert_eq!(stats.shared_b_groups, 1);
    assert_eq!(stats.batched_jobs, n_jobs);

    // Baseline: the same traffic submitted individually packs B per job.
    let individual = server(cfg(4, 16));
    for (i, a) in many_a.into_iter().enumerate() {
        individual
            .submit(GemmJob { id: i as u64, a: a.into(), b: b.clone().into(), run: Some(run) })
            .unwrap()
            .wait()
            .unwrap();
    }
    assert_eq!(individual.metrics().b_panel_packs(), n_jobs);
    assert_eq!(individual.metrics().panels_shared(), 0);
}

#[test]
fn registered_b_bit_identical_to_inline_across_ragged_shapes() {
    // The registry acceptance gate: submissions through a registered
    // WeightHandle must be bit-identical to inline submissions — the
    // cached pack IS the pack an inline call would build, for ragged
    // prime/odd shapes hitting every packing edge, on the batched and
    // the lone-job path alike, across repeated cache-hitting calls.
    let run = RunConfig::square(2, 16);
    for (k, n, ms, seed) in [
        (13usize, 29usize, vec![7usize, 31, 1, 17], 2600u64),
        (23, 17, vec![19, 3, 41], 2700),
        (5, 53, vec![37, 11, 13, 9, 2], 2800),
    ] {
        let b = Matrix::random(k, n, seed);
        let many_a: Vec<Matrix> = ms
            .iter()
            .enumerate()
            .map(|(i, &m)| Matrix::random(m, k, seed + 1 + i as u64))
            .collect();

        // Inline shared batch on its own server — the baseline bits.
        let inline = server(cfg(4, 16));
        let inline_results = inline
            .submit_batched_gemm(b.clone(), many_a.clone(), Some(run))
            .unwrap()
            .wait_all()
            .unwrap();

        // The same batch through a registered handle, twice: the
        // second call resolves from cache and must not perturb a bit.
        let registered = server(cfg(4, 16));
        let h = registered.register_b(b.clone()).unwrap();
        for call in 0..2 {
            let results = registered
                .submit_batched_gemm(h, many_a.clone(), Some(run))
                .unwrap()
                .wait_all()
                .unwrap();
            for ((r, want), a) in results.iter().zip(&inline_results).zip(&many_a) {
                assert_eq!(
                    r.c.data, want.c.data,
                    "call {call}: registered result for {}x{k}x{n} diverged",
                    a.rows
                );
            }
        }
        assert_eq!(registered.metrics().b_panel_packs(), 1);
        assert_eq!(registered.metrics().registry_hits(), 1);
        // Lone registered submits reuse the same cached pack and agree.
        for (i, (a, want)) in many_a.iter().zip(&inline_results).enumerate() {
            let r = registered
                .submit(GemmJob { id: i as u64, a: a.clone().into(), b: h.into(), run: Some(run) })
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(r.c.data, want.c.data);
            // And both agree with the oracle (not just with each other).
            assert!(r.c.allclose(&a.matmul(&b), 1e-4));
        }
        assert_eq!(
            registered.metrics().b_panel_packs(),
            1,
            "lone submits reuse the same cached pack"
        );
    }
}

#[test]
fn registered_calls_conserve_one_pack_vs_inline_baseline() {
    // Pack conservation across CALLS, metrics-asserted: four successive
    // batched calls under one handle perform exactly one B pack; the
    // same four calls with an inline B pack four times.
    let run = Some(RunConfig::square(2, 16));
    let b = Matrix::random(19, 27, 5000);
    let calls = 4u64;

    let registered = server(cfg(4, 16));
    let h = registered.register_b(b.clone()).unwrap();
    for call in 0..calls {
        let many_a: Vec<Matrix> =
            (0..3u64).map(|i| Matrix::random(21, 19, 5001 + 10 * call + i)).collect();
        let wants: Vec<Matrix> = many_a.iter().map(|a| a.matmul(&b)).collect();
        let results =
            registered.submit_batched_gemm(h, many_a, run).unwrap().wait_all().unwrap();
        for (r, want) in results.iter().zip(&wants) {
            assert!(r.c.allclose(want, 1e-4));
        }
    }
    let m = registered.metrics();
    assert_eq!(m.b_panel_packs(), 1, "one pack across all four calls");
    assert_eq!(m.registry_misses(), 1);
    assert_eq!(m.registry_hits(), calls - 1);

    // Inline baseline: the identical traffic repacks per call.
    let inline = server(cfg(4, 16));
    for call in 0..calls {
        let many_a: Vec<Matrix> =
            (0..3u64).map(|i| Matrix::random(21, 19, 5001 + 10 * call + i)).collect();
        inline.submit_batched_gemm(b.clone(), many_a, run).unwrap().wait_all().unwrap();
    }
    assert_eq!(inline.metrics().b_panel_packs(), calls, "inline packs once per call");
    assert_eq!(inline.metrics().registry_hits(), 0);
}

#[test]
fn registry_eviction_under_tight_budget_keeps_results_correct() {
    // A 1-byte budget forces every resolution over budget: unpinned
    // packs evict, handles stay valid (evicted packs transparently
    // repack), and results stay correct throughout.
    let mut c = cfg(4, 16);
    c.registry_budget_bytes = 1;
    let srv = server(c);
    let b1 = Matrix::random(16, 24, 3100);
    let b2 = Matrix::random(16, 24, 3101);
    let h1 = srv.register_b(b1.clone()).unwrap();
    let h2 = srv.register_b(b2.clone()).unwrap();
    let run = Some(RunConfig::square(2, 16));
    for round in 0..3u64 {
        for (j, (h, b)) in [(h1, &b1), (h2, &b2)].into_iter().enumerate() {
            let a = Matrix::random(20, 16, 3200 + 10 * round + j as u64);
            let want = a.matmul(b);
            let r = srv
                .submit(GemmJob { id: round, a: a.into(), b: h.into(), run })
                .unwrap()
                .wait()
                .unwrap();
            assert!(r.c.allclose(&want, 1e-4), "round {round} handle {j}");
        }
    }
    assert_eq!(srv.metrics().jobs(), 6);
    // Deterministic pressure on the registry surface itself: a fresh
    // block-size variant is a guaranteed miss, and once its Arc is
    // dropped it is unpinned — the next insert must evict it (and any
    // other unpinned pack) to chase the 1-byte budget.
    let reg = srv.operand_registry();
    drop(reg.resolve_pack(h1, 8).unwrap());
    let before = srv.metrics().registry_evictions();
    drop(reg.resolve_pack(h2, 8).unwrap());
    assert!(
        srv.metrics().registry_evictions() > before,
        "unpinned LRU pack must evict under a 1-byte budget"
    );
    // Both weights survived every eviction (packs evict, matrices stay).
    assert_eq!(srv.stats().registered_weights, 2);
}

#[test]
fn try_submit_batched_gemm_sheds_with_operands_returned() {
    // The load-shedding contract extended to shared-B groups: a shed
    // batch hands every operand back intact; an admitted batch must
    // complete correctly. Nothing is ever silently dropped.
    let srv = server(cfg(2, 2));
    let run = Some(RunConfig::square(2, 16));
    let b = Matrix::random(16, 32, 4000);
    let mut admitted = Vec::new();
    let mut shed = 0usize;
    let batches = 60u64;
    for j in 0..batches {
        let many_a: Vec<Matrix> =
            (0..2u64).map(|i| Matrix::random(24, 16, 4100 + 10 * j + i)).collect();
        let wants: Vec<Matrix> = many_a.iter().map(|a| a.matmul(&b)).collect();
        match srv.try_submit_batched_gemm(b.clone(), many_a, run) {
            Ok(group) => admitted.push((group, wants)),
            Err(TrySubmitBatchedError::Full { b: back, many_a }) => {
                assert_eq!(back.inline_dims(), Some((16, 32)), "B must come back intact");
                assert_eq!(many_a.len(), 2);
                assert!(many_a.iter().all(|a| (a.rows, a.cols) == (24, 16)));
                shed += 1;
            }
            Err(e) => panic!("unexpected rejection: {e:?}"),
        }
    }
    assert!(!admitted.is_empty());
    let mut completed = 0usize;
    for (group, wants) in admitted {
        for (r, want) in group.wait_all().unwrap().iter().zip(&wants) {
            assert!(r.c.allclose(want, 1e-4));
            completed += 1;
        }
    }
    assert_eq!(srv.metrics().jobs() as usize, completed);
    assert_eq!(completed / 2 + shed, batches as usize, "admitted + shed covers every batch");
}

#[test]
fn try_submit_sheds_load_without_losing_jobs() {
    // try_submit either admits a submission (which must then complete
    // correctly) or hands it back intact inside `SubmitError::Full` —
    // never silently drops it.
    let srv = server(cfg(2, 2));
    let run = RunConfig::square(2, 16);
    let mut admitted = Vec::new();
    let mut rejected = 0usize;
    for j in 0..100u64 {
        let a = Matrix::random(32, 16, j);
        let b = Matrix::random(16, 32, j + 200);
        let want = a.matmul(&b);
        match srv.try_submit(Submission::gemm(a, b).id(j).run(run)) {
            Ok(f) => admitted.push((f, want)),
            Err(SubmitError::Full(s)) => {
                assert_eq!(s.jobs(), 1);
                match s.into_kind() {
                    SubmissionKind::Gemm { a, b } => {
                        assert_eq!(a.inline_dims(), Some((32, 16)), "A must come back intact");
                        assert_eq!(b.inline_dims(), Some((16, 32)), "B must come back intact");
                    }
                    other => panic!("wrong kind handed back: {other:?}"),
                }
                rejected += 1;
            }
            Err(e) => panic!("unexpected rejection: {e}"),
        }
    }
    assert!(!admitted.is_empty());
    for (f, want) in admitted {
        assert!(f.wait_one().unwrap().c.allclose(&want, 1e-4));
    }
    // Conservation: admitted + rejected covers every submission.
    assert_eq!(srv.metrics().jobs() as usize + rejected, 100);
}

#[test]
fn async_and_blocking_paths_bit_identical_over_ragged_shapes() {
    // The api-redesign acceptance gate: `submit_async` + wait and
    // `submit_blocking` must produce bit-identical results — same
    // admission queue, same dispatch, same workers — across ragged
    // prime/odd shapes hitting every packing edge, on the lone-GEMM and
    // the shared-B path alike.
    let run = RunConfig::square(2, 16);
    for (m, k, n, seed) in [
        (7usize, 13usize, 29usize, 9100u64),
        (31, 23, 17, 9200),
        (1, 5, 53, 9300),
        (37, 11, 19, 9400),
    ] {
        let a = Matrix::random(m, k, seed);
        let b = Matrix::random(k, n, seed + 1);
        let srv_async = server(cfg(4, 16));
        let r_async = srv_async
            .submit_async(Submission::gemm(a.clone(), b.clone()).run(run))
            .unwrap()
            .wait_one()
            .unwrap();
        let srv_blocking = server(cfg(4, 16));
        let r_blocking = srv_blocking
            .submit_blocking(Submission::gemm(a.clone(), b.clone()).run(run))
            .unwrap();
        assert_eq!(r_blocking.len(), 1);
        assert_eq!(
            r_async.c.data, r_blocking[0].c.data,
            "async vs blocking diverged for {m}x{k}x{n}"
        );
        // And both agree with the oracle (not just with each other).
        assert!(r_async.c.allclose(&a.matmul(&b), 1e-4));
    }

    // Shared-B batch: member-for-member identity across the two paths.
    let b = Matrix::random(13, 29, 9500);
    let many_a: Vec<Matrix> = [7usize, 31, 1, 17]
        .iter()
        .enumerate()
        .map(|(i, &m)| Matrix::random(m, 13, 9501 + i as u64))
        .collect();
    let srv_async = server(cfg(4, 16));
    let r_async = srv_async
        .submit_async(Submission::batched(b.clone(), many_a.clone()).run(run))
        .unwrap()
        .wait()
        .unwrap();
    let srv_blocking = server(cfg(4, 16));
    let r_blocking = srv_blocking
        .submit_blocking(Submission::batched(b.clone(), many_a.clone()).run(run))
        .unwrap();
    assert_eq!(r_async.len(), r_blocking.len());
    for (i, (x, y)) in r_async.iter().zip(&r_blocking).enumerate() {
        assert_eq!(x.c.data, y.c.data, "shared-B member {i} diverged across paths");
        assert!(x.c.allclose(&many_a[i].matmul(&b), 1e-4));
    }
}

#[test]
fn drr_fairness_served_ratio_tracks_weights() {
    // Two tenants push identical backlogged streams; the light tenant
    // submits its WHOLE stream first. Under FIFO admission the first
    // half of completions would be almost entirely light-tenant jobs;
    // under weighted DRR the heavy (weight 5) tenant must hold a clear
    // majority of early service despite arriving second.
    let light = TenantId(1);
    let heavy = TenantId(2);
    let mut c = cfg(1, 64);
    c.default_run = Some(RunConfig::square(2, 16));
    let srv = server(c);
    srv.configure_tenant(light, TenantConfig { weight: 1, ..Default::default() }).unwrap();
    srv.configure_tenant(heavy, TenantConfig { weight: 5, ..Default::default() }).unwrap();

    let per = 16usize;
    let run = RunConfig::square(2, 16);
    // Pre-generate all operands so the submit loop is a pure push burst
    // — far faster than the dispatcher's pop+plan+pack, so the queue is
    // backlogged and DRR (not arrival order) decides service.
    let make = |t: u32, j: usize| {
        let seed = (t as usize * 100 + j) as u64;
        (Matrix::random(48, 32, seed), Matrix::random(32, 48, seed + 50))
    };
    let streams: Vec<(TenantId, Vec<(Matrix, Matrix)>)> = vec![
        (light, (0..per).map(|j| make(1, j)).collect()),
        (heavy, (0..per).map(|j| make(2, j)).collect()),
    ];
    let mut futures = Vec::with_capacity(2 * per);
    for (tenant, jobs) in streams {
        for (j, (a, b)) in jobs.into_iter().enumerate() {
            futures.push((
                tenant,
                srv.submit_async(Submission::gemm(a, b).id(j as u64).run(run).tenant(tenant))
                    .unwrap(),
            ));
        }
    }

    // One waiter thread per future records its completion instant; the
    // single worker serializes execution, so the sorted timestamps are
    // the service order.
    let order = std::sync::Mutex::new(Vec::with_capacity(2 * per));
    std::thread::scope(|s| {
        for (tenant, f) in futures {
            let order = &order;
            s.spawn(move || {
                f.wait().unwrap();
                order.lock().unwrap().push((std::time::Instant::now(), tenant));
            });
        }
    });
    let mut order = order.into_inner().unwrap();
    assert_eq!(order.len(), 2 * per);
    order.sort_by_key(|(t, _)| *t);

    let first_half = &order[..per];
    let heavy_served = first_half.iter().filter(|(_, t)| *t == heavy).count();
    let light_served = per - heavy_served;
    assert!(
        heavy_served > light_served,
        "weight-5 tenant served {heavy_served}/{per} of the first half \
         (light tenant, weight 1, arrived first and took {light_served}) — \
         DRR is not tracking weights"
    );

    // Totals are conserved per tenant regardless of shaping.
    let stats = srv.stats();
    let totals: std::collections::BTreeMap<TenantId, u64> =
        stats.tenants.iter().map(|(id, c)| (*id, c.jobs)).collect();
    assert_eq!(totals.get(&light), Some(&(per as u64)));
    assert_eq!(totals.get(&heavy), Some(&(per as u64)));
}

#[test]
fn quota_rejection_hands_submission_back_conserved() {
    // A tenant capped at 2 in-flight jobs bursts 20 submissions: every
    // one is either admitted (and completes correctly) or handed back
    // intact inside `SubmitError::QuotaExceeded` — and once the burst
    // drains, the quota slots are fully released.
    let tenant = TenantId(7);
    let srv = server(cfg(2, 64));
    srv.configure_tenant(
        tenant,
        TenantConfig { weight: 1, max_inflight_jobs: Some(2), ..Default::default() },
    )
    .unwrap();
    let run = RunConfig::square(2, 16);
    let mut admitted = Vec::new();
    let mut quota_rejected = 0usize;
    for j in 0..20u64 {
        let a = Matrix::random(32, 16, j);
        let b = Matrix::random(16, 32, j + 900);
        let want = a.matmul(&b);
        match srv.try_submit(Submission::gemm(a, b).id(j).run(run).tenant(tenant)) {
            Ok(f) => admitted.push((f, want)),
            Err(SubmitError::QuotaExceeded { submission, tenant: t }) => {
                assert_eq!(t, tenant);
                match submission.into_kind() {
                    SubmissionKind::Gemm { a, b } => {
                        assert_eq!(a.inline_dims(), Some((32, 16)), "A must come back intact");
                        assert_eq!(b.inline_dims(), Some((16, 32)), "B must come back intact");
                    }
                    other => panic!("wrong kind handed back: {other:?}"),
                }
                quota_rejected += 1;
            }
            Err(SubmitError::Full(_)) => panic!("queue is sized to hold the whole burst"),
            Err(e) => panic!("unexpected rejection: {e}"),
        }
    }
    // A tight 20-submission burst against a 2-job cap must trip the
    // quota at least once (job service is slower than submission).
    assert!(quota_rejected > 0, "quota never engaged");
    for (f, want) in admitted {
        assert!(f.wait_one().unwrap().c.allclose(&want, 1e-4));
    }
    // Conservation: admitted + rejected covers every submission.
    assert_eq!(srv.metrics().jobs() as usize + quota_rejected, 20);

    // All slots released: the tenant is idle again, so a fresh
    // submission admits immediately.
    let a = Matrix::random(32, 16, 990);
    let b = Matrix::random(16, 32, 991);
    let f = srv.try_submit(Submission::gemm(a, b).run(run).tenant(tenant)).unwrap();
    f.wait().unwrap();
}

#[test]
fn deadline_counters_split_hits_from_misses() {
    // Deadline accounting, exactly: jobs under a generous deadline
    // count as deadline jobs but not misses; jobs under an
    // already-expired deadline count as both; jobs with no deadline
    // count in neither.
    let srv = server(cfg(2, 16));
    let run = RunConfig::square(2, 16);
    let mut futures = Vec::new();
    for j in 0..4u64 {
        let a = Matrix::random(24, 16, j);
        let b = Matrix::random(16, 24, j + 50);
        futures.push(
            srv.submit_async(
                Submission::gemm(a, b).id(j).run(run).deadline(Duration::from_secs(3600)),
            )
            .unwrap(),
        );
    }
    for j in 10..13u64 {
        let a = Matrix::random(24, 16, j);
        let b = Matrix::random(16, 24, j + 50);
        futures.push(
            srv.submit_async(Submission::gemm(a, b).id(j).run(run).deadline(Duration::ZERO))
                .unwrap(),
        );
    }
    for f in futures {
        f.wait().unwrap();
    }
    let stats = srv.stats();
    assert_eq!(stats.deadline_jobs, 7, "every deadline-carrying job counted");
    assert_eq!(stats.deadline_misses, 3, "exactly the expired-deadline jobs missed");
    let by_tenant: u64 = stats.tenants.iter().map(|(_, c)| c.deadline_misses).sum();
    assert_eq!(by_tenant, 3, "per-tenant misses sum to the global counter");

    // No deadline -> counted in neither.
    let a = Matrix::random(24, 16, 99);
    let b = Matrix::random(16, 24, 98);
    srv.submit_blocking(Submission::gemm(a, b).run(run)).unwrap();
    let stats = srv.stats();
    assert_eq!((stats.deadline_jobs, stats.deadline_misses), (7, 3));
    assert!(stats.to_string().contains("deadline(miss/ddl)=3/7"));
}

#[test]
fn steals_balance_and_zero_copy_hold_under_serving() {
    // Aggregated WQM statistics stay coherent when many jobs flow
    // through the shared pool, and the golden path stays zero-copy.
    let srv = server(cfg(4, 32));
    let run = RunConfig::square(4, 16);
    let mut pending = Vec::new();
    for j in 0..24u64 {
        let a = Matrix::random(64, 24, j);
        let b = Matrix::random(24, 64, j + 77);
        let want = a.matmul(&b);
        pending.push((
            srv.submit(GemmJob { id: j, a: a.into(), b: b.into(), run: Some(run) }).unwrap(),
            want,
        ));
    }
    for (t, want) in pending {
        assert!(t.wait().unwrap().c.allclose(&want, 1e-4));
    }
    let m = srv.metrics();
    assert_eq!(m.panel_copies(), 0);
    // Intra-job steals are bounded by total tasks; cross-job steals are
    // bounded by total pops (sanity, not exact accounting).
    assert!(m.steals() <= m.tasks());
    assert!(m.cross_job_steals() <= m.tasks());
    srv.shutdown();
}

#[test]
fn flight_recorder_conserves_submissions_and_telescopes_under_load() {
    // Mixed traffic with tracing on and real thread contention: every
    // sub-job appears in the trace exactly once with a terminal event,
    // the five stage spans of each completed job telescope to its
    // end-to-end latency, per-worker tallies partition the job's
    // tasks, and every completion carries a predicted-vs-measured
    // drift record.
    let mut c = cfg(4, 64);
    c.trace_capacity = 8192;
    let srv = server(c);
    let run = RunConfig::square(2, 16);
    let mut futures = Vec::new();
    for j in 0..12u64 {
        let a = Matrix::random(48, 32, j);
        let b = Matrix::random(32, 40, j + 400);
        futures.push(srv.submit_async(Submission::gemm(a, b).id(j).run(run)).unwrap());
    }
    // A shared-B batch rides along so group members are traced too.
    let b = Matrix::random(32, 40, 999);
    let many_a: Vec<Matrix> =
        (0..4u64).map(|i| Matrix::random(48, 32, 700 + i)).collect();
    futures.push(srv.submit_async(Submission::batched(b, many_a).run(run)).unwrap());
    for f in futures {
        f.wait().unwrap();
    }

    let traces = srv.trace_snapshot().job_traces();
    assert_eq!(traces.len(), 16, "12 lone jobs + 4 batch members, each traced once");
    for t in &traces {
        assert_eq!(t.terminal, Terminal::Done, "uid {} not done", t.uid);
        let stages = t.stage_secs().expect("done job has all five stages");
        let e2e = t.end_to_end_secs().unwrap();
        assert!(
            (stages.iter().sum::<f64>() - e2e).abs() < 1e-9,
            "uid {}: stages sum {} != e2e {}",
            t.uid,
            stages.iter().sum::<f64>(),
            e2e
        );
        assert!(t.tasks > 0);
        assert_eq!(
            t.workers.iter().map(|w| w.tasks).sum::<u64>(),
            t.tasks,
            "uid {}: worker tallies must partition the job's tasks",
            t.uid
        );
        assert_eq!(t.workers.iter().map(|w| w.stolen).sum::<u64>(), t.stolen_tasks);
        assert!(t.predicted_secs.is_some() && t.measured_secs.is_some());
    }

    // The rollups surface in stats(): per-stage percentiles, drift,
    // and the per-worker breakdown agreeing with the trace.
    let stats = srv.stats();
    let drift = stats.drift.expect("16 completions must price drift");
    assert_eq!(drift.count, 16);
    assert!(drift.min <= drift.mean && drift.mean <= drift.max);
    let stages = stats.stage_p50_p95_secs.expect("tracing on => stage rollup");
    for (p50, p95) in stages {
        assert!(p50 <= p95);
    }
    assert_eq!(stats.per_worker_tasks.len(), 4);
    let traced: u64 = traces.iter().map(|t| t.tasks).sum();
    assert_eq!(stats.per_worker_tasks.iter().sum::<u64>(), traced);

    // The JSONL export carries one line per job trace.
    let snap = srv.trace_snapshot();
    let mut out = Vec::new();
    snap.exporter().write_jsonl(&mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    assert_eq!(text.lines().count(), 16);
    assert!(text.lines().all(|l| l.contains("\"terminal\":\"done\"")));
    srv.shutdown();
}

#[test]
fn disabled_tracing_stays_dark_under_serving() {
    // The default config (trace_capacity = 0) must record nothing —
    // the flight recorder is pay-for-what-you-use.
    let srv = server(cfg(2, 16));
    let run = RunConfig::square(2, 16);
    for j in 0..4u64 {
        let a = Matrix::random(32, 16, j);
        let b = Matrix::random(16, 32, j + 40);
        srv.submit_blocking(Submission::gemm(a, b).id(j).run(run)).unwrap();
    }
    assert!(!srv.trace_enabled());
    let snap = srv.trace_snapshot();
    assert_eq!(snap.recorded, 0);
    assert!(snap.events.is_empty());
    let stats = srv.stats();
    assert_eq!((stats.trace_recorded, stats.trace_dropped), (0, 0));
    assert!(stats.stage_p50_p95_secs.is_none());
    // Per-worker execution tallies are independent of tracing.
    assert!(stats.per_worker_tasks.iter().sum::<u64>() > 0);
    srv.shutdown();
}
