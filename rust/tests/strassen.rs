//! Strassen correctness against the scalar oracle over ragged shapes.
//!
//! The planner's whole pipeline runs per case: Section-IV padding to a
//! `2^depth` multiple, quadrant views, schedule-driven operand forming
//! (Winograd by default, classic on request), the 7-way job-group
//! fan-out with fused leaf packing through a real `JobServer`, the
//! parallel recursion walk, and the arena-backed recombination. Every
//! result is compared against the naive triple-loop oracle with an
//! explicit FP32 tolerance.

use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::coordinator::{JobServer, NumericsEngine, ServerConfig};
use multi_array::gemm::Matrix;
use multi_array::strassen::{multiply, Cutoff, StrassenAlgo, StrassenConfig};

/// Relative tolerance (scaled by `max |C|`, see `Matrix::allclose`) for
/// Strassen results. The quadrant sums double operand magnitudes per
/// level and reassociate the additions, so the error grows with depth;
/// a numpy port measured worst-case relative error ~2e-6 at depth 3
/// over random `[-1, 1)` operands — 1e-3 leaves three orders of margin.
/// The Winograd form chains sums one step deeper (S2 = S1 - A11,
/// S4 = A12 - S2) but stays within the same bound at these depths.
const TOL: f32 = 1e-3;

/// 33 ragged shapes: primes, odd dims, degenerate 1s, mixed
/// power-of-two/ragged, and rectangular aspect ratios.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (2, 3, 5),
    (3, 2, 2),
    (5, 7, 3),
    (7, 7, 7),
    (8, 8, 8),
    (9, 11, 13),
    (13, 8, 21),
    (16, 16, 16),
    (17, 19, 23),
    (23, 29, 31),
    (29, 13, 7),
    (31, 31, 31),
    (32, 48, 32),
    (33, 17, 65),
    (37, 53, 41),
    (41, 43, 47),
    (47, 23, 59),
    (53, 59, 61),
    (61, 1, 61),
    (64, 64, 64),
    (65, 33, 17),
    (67, 71, 73),
    (79, 83, 89),
    (83, 101, 67),
    (89, 97, 101),
    (96, 128, 64),
    (97, 101, 103),
    (101, 127, 103),
    (107, 109, 113),
    (113, 127, 127),
    (127, 113, 109),
    (131, 137, 139),
];

fn server() -> JobServer {
    let cfg = ServerConfig {
        workers: 4,
        queue_capacity: 32,
        batch_max_tasks: 4,
        batch_window: 4,
        cross_job_stealing: true,
        default_run: Some(RunConfig::square(2, 16)),
        ..ServerConfig::default()
    };
    JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), cfg).unwrap()
}

fn cfg(cutoff: Cutoff) -> StrassenConfig {
    StrassenConfig {
        cutoff,
        run: Some(RunConfig::square(2, 16)),
        ..StrassenConfig::default()
    }
}

#[test]
fn ragged_shapes_match_oracle_one_level() {
    let srv = server();
    for (i, &(m, k, n)) in SHAPES.iter().enumerate() {
        let a = Matrix::random(m, k, 1000 + i as u64);
        let b = Matrix::random(k, n, 2000 + i as u64);
        let want = a.matmul(&b);
        let r = multiply(&srv, &a, &b, &cfg(Cutoff::Depth(1))).unwrap();
        assert_eq!((r.c.rows, r.c.cols), (m, n), "{m}x{k}x{n}: result shape");
        assert!(
            r.c.allclose(&want, TOL),
            "{m}x{k}x{n} depth {}: max err {}",
            r.depth,
            r.c.max_abs_diff(&want)
        );
        // Shapes with every dim >= 2 must actually recurse; each level
        // spawns 7 sub-multiplies, never the direct split's 8.
        if m >= 2 && k >= 2 && n >= 2 {
            assert_eq!(r.depth, 1, "{m}x{k}x{n}");
            assert_eq!(r.leaf_gemms, 7);
            assert!((r.fanout(0) - 7.0).abs() < 1e-12);
        }
    }
}

#[test]
fn ragged_shapes_match_oracle_two_levels() {
    let srv = server();
    for (i, &(m, k, n)) in SHAPES.iter().enumerate() {
        if m < 4 || k < 4 || n < 4 {
            continue; // cannot hold two levels
        }
        let a = Matrix::random(m, k, 3000 + i as u64);
        let b = Matrix::random(k, n, 4000 + i as u64);
        let want = a.matmul(&b);
        let r = multiply(&srv, &a, &b, &cfg(Cutoff::Depth(2))).unwrap();
        assert_eq!(r.depth, 2, "{m}x{k}x{n}");
        assert_eq!(r.leaf_gemms, 49);
        assert!(r.c.allclose(&want, TOL), "{m}x{k}x{n}: max err {}", r.c.max_abs_diff(&want));
    }
}

#[test]
fn winograd_and_classic_match_oracle_depths_1_to_3() {
    // The two schedules against the oracle and against each other, over
    // ragged prime/odd shapes at every forced depth — with the per-node
    // combine-op counts (15 vs 18) and the fused-leaf temp savings
    // asserted from the report's metrics, not assumed.
    let srv = server();
    let shapes = [(17, 19, 23), (29, 13, 7), (33, 17, 65), (41, 43, 47)];
    for (i, &(m, k, n)) in shapes.iter().enumerate() {
        for depth in 1..=3usize {
            let a = Matrix::random(m, k, 5000 + i as u64);
            let b = Matrix::random(k, n, 6000 + i as u64);
            let want = a.matmul(&b);
            let wino = multiply(&srv, &a, &b, &cfg(Cutoff::Depth(depth))).unwrap();
            let classic = multiply(
                &srv,
                &a,
                &b,
                &StrassenConfig { algo: StrassenAlgo::Classic, ..cfg(Cutoff::Depth(depth)) },
            )
            .unwrap();
            assert_eq!(wino.algo, StrassenAlgo::Winograd);
            assert_eq!(classic.algo, StrassenAlgo::Classic);
            assert_eq!(wino.depth, classic.depth, "{m}x{k}x{n} depth {depth}");
            assert!(
                wino.c.allclose(&want, TOL),
                "{m}x{k}x{n} depth {depth} winograd: max err {}",
                wino.c.max_abs_diff(&want)
            );
            assert!(
                classic.c.allclose(&want, TOL),
                "{m}x{k}x{n} depth {depth} classic: max err {}",
                classic.c.max_abs_diff(&want)
            );
            assert!(
                wino.c.allclose(&classic.c, TOL),
                "{m}x{k}x{n} depth {depth}: schedules disagree by {}",
                wino.c.max_abs_diff(&classic.c)
            );
            if wino.depth > 0 {
                assert!((wino.combine.ops_per_node() - 15.0).abs() < 1e-12);
                assert!((classic.combine.ops_per_node() - 18.0).abs() < 1e-12);
                // Fused leaves: Winograd materializes 4 of 14 operand
                // temps per leaf node, classic none at all.
                let leaves = wino.level_nodes[wino.depth - 1];
                assert_eq!(wino.combine.temps_avoided, 10 * leaves);
                assert_eq!(classic.combine.temps_avoided, 14 * leaves);
                assert!(
                    wino.combine.temps_avoided >= wino.combine.nodes,
                    "at least one temp set saved per node"
                );
            }
        }
    }
}

#[test]
fn parallel_walk_is_bit_identical_and_deterministic() {
    // One shared server: the parallel tree walk must reproduce the
    // sequential walk bit for bit (fixed join order, zeroed arena
    // buffers) and repeat runs must reproduce themselves.
    let srv = server();
    let (m, k, n) = (37, 53, 41);
    let a = Matrix::random(m, k, 91);
    let b = Matrix::random(k, n, 92);
    let seq = multiply(
        &srv,
        &a,
        &b,
        &StrassenConfig { parallel: false, ..cfg(Cutoff::Depth(3)) },
    )
    .unwrap();
    let par = multiply(&srv, &a, &b, &cfg(Cutoff::Depth(3))).unwrap();
    assert_eq!(par.depth, 3);
    assert_eq!(par.c.data, seq.c.data, "parallel result diverged from sequential");
    assert_eq!(par.leaf_gemms, seq.leaf_gemms);
    assert_eq!(par.level_nodes, seq.level_nodes);
    assert_eq!(par.level_spawns, seq.level_spawns);
    assert_eq!(par.combine, seq.combine, "merged sub-tree counters match serial walk");
    for round in 0..2 {
        let again = multiply(&srv, &a, &b, &cfg(Cutoff::Depth(3))).unwrap();
        assert_eq!(again.c.data, par.c.data, "parallel round {round} not deterministic");
    }
    assert!(par.c.allclose(&a.matmul(&b), TOL));
}

#[test]
fn deep_forced_recursion_recombines_correctly() {
    // Three levels on a prime-dimension problem: 343 leaf GEMMs over
    // padded 144x144x144 quadrant trees, recombined through the arena.
    // Sequential walk: the arena-reuse ratio below relies on one arena
    // threading the whole tree (the parallel walk splits it per thread).
    let srv = server();
    let (m, k, n) = (131, 137, 139);
    let a = Matrix::random(m, k, 77);
    let b = Matrix::random(k, n, 78);
    let want = a.matmul(&b);
    let r = multiply(
        &srv,
        &a,
        &b,
        &StrassenConfig { parallel: false, ..cfg(Cutoff::Depth(3)) },
    )
    .unwrap();
    assert_eq!(r.depth, 3);
    assert_eq!(r.leaf_gemms, 343);
    assert_eq!(r.level_nodes, vec![1, 7, 49]);
    assert_eq!(r.level_spawns, vec![7, 49, 343]);
    // Padding rounds every dim up to a multiple of 2^3.
    assert_eq!(r.padded, (136, 144, 144));
    assert!(r.c.allclose(&want, TOL), "max err {} at depth 3", r.c.max_abs_diff(&want));
    assert!(r.arena.reuses > r.arena.fresh_allocs, "deep recursion must mostly recycle");
}

#[test]
fn model_cutoff_is_exercised_end_to_end() {
    // At test scale the model always says "direct" — the point is that
    // the Model path (crossover + fallback) runs end to end.
    let srv = server();
    let a = Matrix::random(96, 64, 5);
    let b = Matrix::random(64, 80, 6);
    let want = a.matmul(&b);
    let r = multiply(&srv, &a, &b, &cfg(Cutoff::Model)).unwrap();
    assert_eq!(r.depth, 0, "96^3-scale sits far below the crossover");
    assert_eq!(r.model.as_ref().unwrap().depth, 0);
    assert_eq!(r.leaf_gemms, 1);
    assert!(r.c.allclose(&want, TOL));
}

#[test]
fn unpinned_leaves_use_server_default_plan() {
    let srv = server();
    let a = Matrix::random(24, 20, 7);
    let b = Matrix::random(20, 28, 8);
    let want = a.matmul(&b);
    let cfg = StrassenConfig { cutoff: Cutoff::Depth(1), run: None, ..StrassenConfig::default() };
    let r = multiply(&srv, &a, &b, &cfg).unwrap();
    assert!(r.c.allclose(&want, TOL));
}

#[test]
fn repeated_multiplies_share_one_server() {
    // The serving-runtime property the subsystem rides on: many
    // Strassen jobs against one persistent pool, tickets never cross.
    let srv = server();
    for i in 0..5u64 {
        let a = Matrix::random(30 + i as usize, 22, 100 + i);
        let b = Matrix::random(22, 26 + i as usize, 200 + i);
        let want = a.matmul(&b);
        let r = multiply(&srv, &a, &b, &cfg(Cutoff::Depth(1))).unwrap();
        assert!(r.c.allclose(&want, TOL), "iteration {i}");
    }
    assert_eq!(srv.metrics().jobs(), 35, "5 runs x 7 leaf GEMMs");
}
