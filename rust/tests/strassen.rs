//! Strassen correctness against the scalar oracle over ragged shapes.
//!
//! The planner's whole pipeline runs per case: Section-IV padding to a
//! `2^depth` multiple, quadrant views, add/sub operand combos, the
//! 7-way job-group fan-out through a real `JobServer`, and the arena-
//! backed recombination. Every result is compared against the naive
//! triple-loop oracle with an explicit FP32 tolerance.

use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::coordinator::{JobServer, NumericsEngine, ServerConfig};
use multi_array::gemm::Matrix;
use multi_array::strassen::{multiply, Cutoff, StrassenConfig};

/// Relative tolerance (scaled by `max |C|`, see `Matrix::allclose`) for
/// Strassen results. The quadrant sums double operand magnitudes per
/// level and reassociate the additions, so the error grows with depth;
/// a numpy port measured worst-case relative error ~2e-6 at depth 3
/// over random `[-1, 1)` operands — 1e-3 leaves three orders of margin.
const TOL: f32 = 1e-3;

/// 33 ragged shapes: primes, odd dims, degenerate 1s, mixed
/// power-of-two/ragged, and rectangular aspect ratios.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (2, 3, 5),
    (3, 2, 2),
    (5, 7, 3),
    (7, 7, 7),
    (8, 8, 8),
    (9, 11, 13),
    (13, 8, 21),
    (16, 16, 16),
    (17, 19, 23),
    (23, 29, 31),
    (29, 13, 7),
    (31, 31, 31),
    (32, 48, 32),
    (33, 17, 65),
    (37, 53, 41),
    (41, 43, 47),
    (47, 23, 59),
    (53, 59, 61),
    (61, 1, 61),
    (64, 64, 64),
    (65, 33, 17),
    (67, 71, 73),
    (79, 83, 89),
    (83, 101, 67),
    (89, 97, 101),
    (96, 128, 64),
    (97, 101, 103),
    (101, 127, 103),
    (107, 109, 113),
    (113, 127, 127),
    (127, 113, 109),
    (131, 137, 139),
];

fn server() -> JobServer {
    let cfg = ServerConfig {
        workers: 4,
        queue_capacity: 32,
        batch_max_tasks: 4,
        batch_window: 4,
        cross_job_stealing: true,
        default_run: Some(RunConfig::square(2, 16)),
        ..ServerConfig::default()
    };
    JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), cfg).unwrap()
}

fn cfg(cutoff: Cutoff) -> StrassenConfig {
    StrassenConfig { cutoff, run: Some(RunConfig::square(2, 16)) }
}

#[test]
fn ragged_shapes_match_oracle_one_level() {
    let srv = server();
    for (i, &(m, k, n)) in SHAPES.iter().enumerate() {
        let a = Matrix::random(m, k, 1000 + i as u64);
        let b = Matrix::random(k, n, 2000 + i as u64);
        let want = a.matmul(&b);
        let r = multiply(&srv, &a, &b, &cfg(Cutoff::Depth(1))).unwrap();
        assert_eq!((r.c.rows, r.c.cols), (m, n), "{m}x{k}x{n}: result shape");
        assert!(
            r.c.allclose(&want, TOL),
            "{m}x{k}x{n} depth {}: max err {}",
            r.depth,
            r.c.max_abs_diff(&want)
        );
        // Shapes with every dim >= 2 must actually recurse; each level
        // spawns 7 sub-multiplies, never the direct split's 8.
        if m >= 2 && k >= 2 && n >= 2 {
            assert_eq!(r.depth, 1, "{m}x{k}x{n}");
            assert_eq!(r.leaf_gemms, 7);
            assert!((r.fanout(0) - 7.0).abs() < 1e-12);
        }
    }
}

#[test]
fn ragged_shapes_match_oracle_two_levels() {
    let srv = server();
    for (i, &(m, k, n)) in SHAPES.iter().enumerate() {
        if m < 4 || k < 4 || n < 4 {
            continue; // cannot hold two levels
        }
        let a = Matrix::random(m, k, 3000 + i as u64);
        let b = Matrix::random(k, n, 4000 + i as u64);
        let want = a.matmul(&b);
        let r = multiply(&srv, &a, &b, &cfg(Cutoff::Depth(2))).unwrap();
        assert_eq!(r.depth, 2, "{m}x{k}x{n}");
        assert_eq!(r.leaf_gemms, 49);
        assert!(r.c.allclose(&want, TOL), "{m}x{k}x{n}: max err {}", r.c.max_abs_diff(&want));
    }
}

#[test]
fn deep_forced_recursion_recombines_correctly() {
    // Three levels on a prime-dimension problem: 343 leaf GEMMs over
    // padded 144x144x144 quadrant trees, recombined through the arena.
    let srv = server();
    let (m, k, n) = (131, 137, 139);
    let a = Matrix::random(m, k, 77);
    let b = Matrix::random(k, n, 78);
    let want = a.matmul(&b);
    let r = multiply(&srv, &a, &b, &cfg(Cutoff::Depth(3))).unwrap();
    assert_eq!(r.depth, 3);
    assert_eq!(r.leaf_gemms, 343);
    assert_eq!(r.level_nodes, vec![1, 7, 49]);
    assert_eq!(r.level_spawns, vec![7, 49, 343]);
    // Padding rounds every dim up to a multiple of 2^3.
    assert_eq!(r.padded, (136, 144, 144));
    assert!(r.c.allclose(&want, TOL), "max err {} at depth 3", r.c.max_abs_diff(&want));
    assert!(r.arena.reuses > r.arena.fresh_allocs, "deep recursion must mostly recycle");
}

#[test]
fn model_cutoff_is_exercised_end_to_end() {
    // At test scale the model always says "direct" — the point is that
    // the Model path (crossover + fallback) runs end to end.
    let srv = server();
    let a = Matrix::random(96, 64, 5);
    let b = Matrix::random(64, 80, 6);
    let want = a.matmul(&b);
    let r = multiply(&srv, &a, &b, &cfg(Cutoff::Model)).unwrap();
    assert_eq!(r.depth, 0, "96^3-scale sits far below the crossover");
    assert_eq!(r.model.as_ref().unwrap().depth, 0);
    assert_eq!(r.leaf_gemms, 1);
    assert!(r.c.allclose(&want, TOL));
}

#[test]
fn unpinned_leaves_use_server_default_plan() {
    let srv = server();
    let a = Matrix::random(24, 20, 7);
    let b = Matrix::random(20, 28, 8);
    let want = a.matmul(&b);
    let cfg = StrassenConfig { cutoff: Cutoff::Depth(1), run: None };
    let r = multiply(&srv, &a, &b, &cfg).unwrap();
    assert!(r.c.allclose(&want, TOL));
}

#[test]
fn repeated_multiplies_share_one_server() {
    // The serving-runtime property the subsystem rides on: many
    // Strassen jobs against one persistent pool, tickets never cross.
    let srv = server();
    for i in 0..5u64 {
        let a = Matrix::random(30 + i as usize, 22, 100 + i);
        let b = Matrix::random(22, 26 + i as usize, 200 + i);
        let want = a.matmul(&b);
        let r = multiply(&srv, &a, &b, &cfg(Cutoff::Depth(1))).unwrap();
        assert!(r.c.allclose(&want, TOL), "iteration {i}");
    }
    assert_eq!(srv.metrics().jobs(), 35, "5 runs x 7 leaf GEMMs");
}
