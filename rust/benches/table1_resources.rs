//! Table I regenerator: post-synthesis resource utilization of the
//! paper's (Pm=4, P=64) design on the XC7VX690T, from the calibrated
//! resource model, plus extrapolations the paper's DSE would need.

use multi_array::config::HardwareConfig;
use multi_array::resources::{self, xc7vx690t, ResourceModel};
use multi_array::util::Bench;

fn print_table() {
    let hw = HardwareConfig::paper();
    let r = resources::report(&hw);
    println!("\n=== Table I: post-synthesis resource utilization ===");
    println!(
        "{:<14} {:>10} {:>10} {:>12} {:>10}",
        "Resource", "DSP48Es", "BRAMs", "Flip-Flops", "LUTs"
    );
    println!(
        "{:<14} {:>10.0} {:>10.1} {:>12.0} {:>10.0}",
        "Utilization", r.usage.dsp, r.usage.bram36, r.usage.ff, r.usage.lut
    );
    println!(
        "{:<14} {:>10.2} {:>10.2} {:>12.2} {:>10.2}",
        "percentage(%)",
        r.percent.dsp,
        r.percent.bram36,
        r.percent.ff,
        r.percent.lut
    );

    // Extrapolation: how far the multi-array design could scale.
    let m = ResourceModel::calibrated();
    let d = xc7vx690t();
    println!("\nextrapolation — max P per Pm on XC7VX690T:");
    for pm in [1usize, 2, 4, 8] {
        println!("  Pm={pm}: max P = {}", m.max_p(pm, &d));
    }
    println!();
}

fn main() {
    print_table();
    let m = ResourceModel::calibrated();
    let d = xc7vx690t();
    Bench::new("table1_resources").run("resource_model_estimate", || {
        let e = m.estimate(4, 64);
        std::hint::black_box(e.max_fraction(&d))
    });
}
