//! Serving-runtime benchmark: 64 mixed-size jobs through one persistent
//! pool, cross-job stealing vs the per-job-pool baseline.
//!
//! The workload is the ISSUE's motivating mix — a few elephants
//! (512x128x512, 64 WQM tasks each) among many single-task mice
//! (64x32x64) — so the per-job-pool baseline visibly idles workers
//! while a mouse holds the pool and the cross-job scheduler does not.
//! Three modes:
//!
//! * `serve64_per_job_pools`  — stealing OFF, batching OFF (baseline:
//!   the pool drains jobs strictly one at a time);
//! * `serve64_cross_steal`    — stealing ON, batching OFF (isolates the
//!   inter-job stealing win);
//! * `serve64_full_system`    — stealing ON, batching ON (the shipped
//!   configuration, small jobs coalesced into super-jobs).
//!
//! Each mode's record carries `worker_idle_frac` (mean across samples)
//! and `cross_job_steals` annotations; the CI gate and BENCH_serving.json
//! consumers compare idle fractions across modes.
//!
//! A fourth pair measures the shared-operand pipeline on a uniform
//! small-GEMM stream that multiplies one B (the im2col inference
//! shape):
//!
//! * `serving_individual_shared_b_workload` — the N jobs submitted
//!   individually (N private B packs);
//! * `serving_batched_shared_b` — the same N jobs through
//!   `submit_batched_gemm` (one B pack; `packs_avoided` annotates the
//!   N-1 the sharing saved). This label is on the CI bench gate;
//! * `serving_registered_weights` — the same batch through one
//!   registered `WeightHandle` on a long-lived server: the warmup pass
//!   is the cold miss that packs, every timed sample is a warm cache
//!   hit (`cache_hits`/`cache_misses` annotations). Also CI-gated;
//! * `serving_registered_attention` — the symmetric-residency flagship:
//!   a transformer attention block re-run over one registered
//!   activation batch (`ActivationBatch`, A side) against registered
//!   Q/K/V/O weights (B side) — after warmup, repeated runs pack
//!   nothing on either side (`a_cache_hits`/`b_cache_hits`
//!   annotations). Also CI-gated;
//! * `serving_registered_attention_bf16` — the same block served at
//!   bf16 on a fresh server (half-width packed panels, widen-on-load
//!   f32 accumulate); its record carries a `dtype` tag so the gate
//!   pairs baseline and fresh runs per precision. Also CI-gated;
//! * `serving_multi_tenant` — the admission front end under tenancy:
//!   two tenants with 1:3 DRR weights push the same backlogged mouse
//!   stream under per-job deadlines; `deadline_miss_frac` and the
//!   per-tenant throughput annotations come from `stats()`. Also
//!   CI-gated.

use std::cell::Cell;

use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::coordinator::{
    GemmJob, JobServer, NumericsEngine, ServerConfig, Submission, TenantConfig, TenantId,
};
use multi_array::gemm::Matrix;
use multi_array::util::Bench;

const NJOBS: usize = 64;
const WORKERS: usize = 4;

/// The job mix: every 8th job an elephant, the rest single-task mice.
/// Returns `(a, b, run)` triples; operands are rebuilt per submission
/// (the server consumes them).
fn workload() -> Vec<(Matrix, Matrix, RunConfig)> {
    (0..NJOBS)
        .map(|j| {
            let seed = j as u64;
            if j % 8 == 0 {
                (
                    Matrix::random(512, 128, seed),
                    Matrix::random(128, 512, seed + 9000),
                    RunConfig::square(4, 64),
                )
            } else {
                (
                    Matrix::random(64, 32, seed),
                    Matrix::random(32, 64, seed + 9000),
                    RunConfig::square(4, 64),
                )
            }
        })
        .collect()
}

fn total_flops(jobs: &[(Matrix, Matrix, RunConfig)]) -> u64 {
    jobs.iter()
        .map(|(a, b, _)| 2 * a.rows as u64 * a.cols as u64 * b.cols as u64)
        .sum()
}

/// Push the whole mix through a fresh server; returns
/// `(worker_idle_frac, cross_job_steals)`.
fn serve_once(
    jobs: &[(Matrix, Matrix, RunConfig)],
    cross_job_stealing: bool,
    batching: bool,
) -> (f64, u64) {
    let cfg = ServerConfig {
        workers: WORKERS,
        queue_capacity: NJOBS,
        batch_max_tasks: if batching { 4 } else { 0 },
        batch_window: if batching { 8 } else { 1 },
        cross_job_stealing,
        default_run: None,
        ..ServerConfig::default()
    };
    let srv = JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), cfg)
        .expect("server construction");
    let futures: Vec<_> = jobs
        .iter()
        .enumerate()
        .map(|(id, (a, b, run))| {
            srv.submit_async(GemmJob {
                id: id as u64,
                a: a.clone().into(),
                b: b.clone().into(),
                run: Some(*run),
            })
            .expect("submit")
        })
        .collect();
    for f in futures {
        f.wait().expect("job result");
    }
    let stats = srv.stats();
    assert_eq!(stats.jobs, NJOBS as u64, "every job must complete");
    (stats.worker_idle_frac, stats.cross_job_steals)
}

fn main() {
    let bench = Bench::new("serving_throughput");
    let jobs = workload();
    let flops = total_flops(&jobs);

    for (label, cross, batching) in [
        ("serve64_per_job_pools", false, false),
        ("serve64_cross_steal", true, false),
        ("serve64_full_system", true, true),
    ] {
        let idle_sum = Cell::new(0.0f64);
        let steal_sum = Cell::new(0.0f64);
        let samples = Cell::new(0u32);
        bench.run_throughput(label, flops, || {
            let (idle, steals) = serve_once(&jobs, cross, batching);
            idle_sum.set(idle_sum.get() + idle);
            steal_sum.set(steal_sum.get() + steals as f64);
            samples.set(samples.get() + 1);
        });
        let n = samples.get().max(1) as f64;
        bench.annotate("worker_idle_frac", idle_sum.get() / n);
        bench.annotate("cross_job_steals", steal_sum.get() / n);
        bench.annotate("jobs", NJOBS as f64);
        bench.annotate("workers", WORKERS as f64);
    }

    // Shared-operand pipeline: the same B under every job. Uniform mice
    // so the win isolated is pack sharing, not scheduling.
    let b = Matrix::random(32, 64, 4242);
    let many_a: Vec<Matrix> =
        (0..NJOBS).map(|j| Matrix::random(64, 32, 5000 + j as u64)).collect();
    let shared_flops = 2 * 64 * 32 * 64 * NJOBS as u64;
    let shared_cfg = || ServerConfig {
        workers: WORKERS,
        queue_capacity: NJOBS,
        batch_max_tasks: 0,
        batch_window: 1,
        cross_job_stealing: true,
        default_run: None,
        ..ServerConfig::default()
    };
    let run = RunConfig::square(4, 64);

    bench.run_throughput("serving_individual_shared_b_workload", shared_flops, || {
        let srv = JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), shared_cfg())
            .expect("server construction");
        let futures: Vec<_> = many_a
            .iter()
            .enumerate()
            .map(|(id, a)| {
                srv.submit_async(GemmJob {
                    id: id as u64,
                    a: a.clone().into(),
                    b: b.clone().into(),
                    run: Some(run),
                })
                .expect("submit")
            })
            .collect();
        for f in futures {
            f.wait().expect("job result");
        }
        assert_eq!(srv.stats().b_panel_packs, NJOBS as u64);
    });
    bench.annotate("b_panel_packs", NJOBS as f64);
    bench.annotate("packs_avoided", 0.0);

    let packs_avoided = Cell::new(0.0f64);
    let shared_samples = Cell::new(0u32);
    bench.run_throughput("serving_batched_shared_b", shared_flops, || {
        let srv = JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), shared_cfg())
            .expect("server construction");
        let results = srv
            .submit_blocking(Submission::batched(b.clone(), many_a.clone()).run(run))
            .expect("batched results");
        assert_eq!(results.len(), NJOBS);
        let stats = srv.stats();
        assert_eq!(stats.b_panel_packs, 1, "shared B must pack once");
        packs_avoided.set(packs_avoided.get() + stats.panels_shared as f64);
        shared_samples.set(shared_samples.get() + 1);
    });
    bench.annotate("b_panel_packs", 1.0);
    bench.annotate(
        "packs_avoided",
        packs_avoided.get() / shared_samples.get().max(1) as f64,
    );
    bench.annotate("jobs", NJOBS as f64);

    // Registered weights: the same shared-B workload through one
    // registered WeightHandle on a single long-lived server — the
    // cross-call operand cache. The warmup pass packs once (the cold
    // miss); every timed sample resolves the cached pack (warm hits),
    // so this label measures the serving path with B pack traffic
    // eliminated entirely. CI-gated.
    let srv = JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), shared_cfg())
        .expect("server construction");
    let handle = srv.register_b(b.clone()).expect("register weight");
    bench.run_throughput("serving_registered_weights", shared_flops, || {
        let results = srv
            .submit_blocking(Submission::batched(handle, many_a.clone()).run(run))
            .expect("registered results");
        assert_eq!(results.len(), NJOBS);
    });
    let stats = srv.stats();
    assert_eq!(stats.b_panel_packs, 1, "registered weight packs once per process");
    bench.annotate("b_panel_packs", stats.b_panel_packs as f64);
    bench.annotate("cache_hits", stats.registry_hits as f64);
    bench.annotate("cache_misses", stats.registry_misses as f64);
    bench.annotate("jobs", NJOBS as f64);
    srv.shutdown();

    // Registered attention: the flagship symmetric-residency workload —
    // one transformer block (Q/K/V/O projections, QK^T, softmax, AV)
    // re-run over one registered activation batch against registered
    // weights on a long-lived server. The warmup pass is the only one
    // that packs either side; every timed sample resolves all four
    // weights and every projection's activation from the cache. CI-gated.
    {
        use multi_array::attention::{
            attention_block_registered, attention_block_registered_dtype, ActivationBatch,
            AttentionWeights,
        };
        use multi_array::gemm::Dtype;
        const D_MODEL: usize = 64;
        const SEQ: usize = 48;
        const BATCH: usize = 4;
        let xs: Vec<Matrix> =
            (0..BATCH as u64).map(|i| Matrix::random(SEQ, D_MODEL, 7000 + i)).collect();
        // Per member: 4 d_model-square projections + QK^T + PV.
        let attn_flops = (BATCH
            * (4 * 2 * SEQ * D_MODEL * D_MODEL + 2 * 2 * SEQ * SEQ * D_MODEL))
            as u64;
        let srv = JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), shared_cfg())
            .expect("server construction");
        let weights = AttentionWeights::random(&srv, D_MODEL, 7100).expect("register weights");
        let abatch = ActivationBatch::register(&srv, &xs).expect("register activations");
        let attn_run = RunConfig::square(4, 48);
        bench.run_throughput("serving_registered_attention", attn_flops, || {
            let outs = attention_block_registered(&srv, &abatch, &weights, Some(attn_run))
                .expect("attention block");
            assert_eq!(outs.len(), BATCH);
        });
        let stats = srv.stats();
        assert_eq!(
            stats.registry_a_misses, BATCH as u64,
            "each activation packs once, ever"
        );
        bench.annotate("a_cache_hits", stats.registry_a_hits as f64);
        bench.annotate("a_cache_misses", stats.registry_a_misses as f64);
        bench.annotate("b_cache_hits", stats.registry_hits as f64);
        bench.annotate("batch", BATCH as f64);
        bench.annotate("seq", SEQ as f64);
        bench.annotate("d_model", D_MODEL as f64);
        bench.annotate_str("dtype", "f32");
        abatch.unregister(&srv).expect("unregister activations");
        weights.unregister(&srv).expect("unregister weights");
        srv.shutdown();

        // The same block served at bf16 on a fresh server: panels pack
        // at half width, the microkernel widens on load and accumulates
        // in f32. Same residency contract — the warmup pass is the only
        // one that packs the bf16 variants. CI-gated next to the f32
        // label; the gate pairs records by (label, dtype).
        let srv = JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), shared_cfg())
            .expect("server construction");
        let weights = AttentionWeights::random(&srv, D_MODEL, 7100).expect("register weights");
        let abatch = ActivationBatch::register(&srv, &xs).expect("register activations");
        bench.run_throughput("serving_registered_attention_bf16", attn_flops, || {
            let outs = attention_block_registered_dtype(
                &srv,
                &abatch,
                &weights,
                Some(attn_run),
                Dtype::Bf16,
            )
            .expect("attention block");
            assert_eq!(outs.len(), BATCH);
        });
        let stats = srv.stats();
        assert_eq!(
            stats.registry_a_misses, BATCH as u64,
            "each activation packs its bf16 variant once, ever"
        );
        bench.annotate("a_cache_hits", stats.registry_a_hits as f64);
        bench.annotate("a_cache_misses", stats.registry_a_misses as f64);
        bench.annotate("b_cache_hits", stats.registry_hits as f64);
        bench.annotate("batch", BATCH as f64);
        bench.annotate("seq", SEQ as f64);
        bench.annotate("d_model", D_MODEL as f64);
        bench.annotate_str("dtype", "bf16");
        abatch.unregister(&srv).expect("unregister activations");
        weights.unregister(&srv).expect("unregister weights");
        srv.shutdown();
    }

    // Multi-tenant admission: two tenants with 1:3 DRR weights push the
    // same backlogged mouse stream through the front end under per-job
    // deadlines. Every job completes (fairness shapes order, not
    // totals); the gate label carries the deadline-miss fraction and
    // each tenant's served throughput. CI-gated.
    {
        const PER_TENANT: usize = 24;
        let mt_flops = 2 * 64 * 32 * 64 * (2 * PER_TENANT) as u64;
        let miss_frac = Cell::new(0.0f64);
        let t0_rate = Cell::new(0.0f64);
        let t1_rate = Cell::new(0.0f64);
        let mt_samples = Cell::new(0u32);
        bench.run_throughput("serving_multi_tenant", mt_flops, || {
            let srv =
                JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), shared_cfg())
                    .expect("server construction");
            srv.configure_tenant(TenantId(0), TenantConfig { weight: 1, ..Default::default() })
                .expect("tenant 0");
            srv.configure_tenant(TenantId(1), TenantConfig { weight: 3, ..Default::default() })
                .expect("tenant 1");
            let start = std::time::Instant::now();
            let mut futures = Vec::with_capacity(2 * PER_TENANT);
            for t in 0..2u32 {
                for j in 0..PER_TENANT {
                    let seed = 6000 + (t as usize * PER_TENANT + j) as u64;
                    let a = Matrix::random(64, 32, seed);
                    futures.push(
                        srv.submit_async(
                            Submission::gemm(a, b.clone())
                                .id(seed)
                                .tenant(TenantId(t))
                                .run(run)
                                .deadline(std::time::Duration::from_millis(250)),
                        )
                        .expect("submit"),
                    );
                }
            }
            for f in futures {
                f.wait().expect("job result");
            }
            let wall = start.elapsed().as_secs_f64().max(1e-9);
            let stats = srv.stats();
            assert_eq!(stats.deadline_jobs, (2 * PER_TENANT) as u64);
            miss_frac
                .set(miss_frac.get() + stats.deadline_misses as f64 / stats.deadline_jobs as f64);
            for (id, c) in &stats.tenants {
                let rate = c.jobs as f64 / wall;
                match id.0 {
                    0 => t0_rate.set(t0_rate.get() + rate),
                    1 => t1_rate.set(t1_rate.get() + rate),
                    _ => {}
                }
            }
            mt_samples.set(mt_samples.get() + 1);
            srv.shutdown();
        });
        let n = mt_samples.get().max(1) as f64;
        bench.annotate("deadline_miss_frac", miss_frac.get() / n);
        bench.annotate("tenant0_weight", 1.0);
        bench.annotate("tenant1_weight", 3.0);
        bench.annotate("tenant0_jobs_per_sec", t0_rate.get() / n);
        bench.annotate("tenant1_jobs_per_sec", t1_rate.get() / n);
        bench.annotate("jobs", (2 * PER_TENANT) as f64);
    }

    if let Err(e) = bench.write_json("BENCH_serving.json") {
        eprintln!("could not write BENCH_serving.json: {e}");
    } else {
        println!("wrote BENCH_serving.json");
    }
}
