//! Fig. 4 regenerator: predicted execution-time bounds (Eq. 7) vs the
//! simulated "actual" time for conv-2, across (N_p, S_i) configurations.
//!
//! The paper's qualitative claims this must reproduce:
//! * the lower bound tracks the measurement when bandwidth is satisfied;
//! * memory-bound configs sit near the upper bound;
//! * (1, 32) beats (2, 16) even though it uses fewer arrays, because its
//!   larger blocks reach higher effective bandwidth.

use multi_array::accelerator::{Accelerator, SimOptions};
use multi_array::analytical;
use multi_array::cnn;
use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::util::Bench;

fn print_figure() {
    let hw = HardwareConfig::paper();
    let acc = Accelerator::new(hw.clone());
    let l = cnn::layer("conv2").unwrap();
    println!("\n=== Fig. 4: conv-2 (128*1200*729) predicted vs simulated ===");
    println!(
        "{:>10} {:>12} {:>12} {:>12} {:>10} {:>6}",
        "(Np,Si)", "lower(ms)", "upper(ms)", "sim(ms)", "GFLOPS", "memB"
    );
    for si in [16usize, 32, 64, 128, 256] {
        for np in analytical::feasible_nps(&hw, si) {
            let run = RunConfig::square(np, si);
            let p =
                analytical::predict(&hw, &run, l.m, l.k, l.n, acc.surface()).unwrap();
            let sim = acc.simulate(&run, l.m, l.k, l.n, &SimOptions::default()).unwrap();
            println!(
                "{:>10} {:>12.3} {:>12.3} {:>12.3} {:>10.1} {:>6}",
                format!("({np},{si})"),
                p.lower * 1e3,
                p.upper * 1e3,
                sim.total_secs * 1e3,
                sim.gflops,
                if p.memory_bound() { "yes" } else { "no" }
            );
        }
    }

    // The paper's crossover callout.
    let s132 = acc
        .simulate(&RunConfig::square(1, 32), l.m, l.k, l.n, &SimOptions::default())
        .unwrap();
    let s216 = acc
        .simulate(&RunConfig::square(2, 16), l.m, l.k, l.n, &SimOptions::default())
        .unwrap();
    println!(
        "\ncrossover check: (1,32) = {:.3} ms vs (2,16) = {:.3} ms  ({})\n",
        s132.total_secs * 1e3,
        s216.total_secs * 1e3,
        if s132.total_secs < s216.total_secs {
            "reproduces the paper: (1,32) wins"
        } else {
            "MISMATCH with the paper"
        }
    );
}

fn main() {
    print_figure();
    let hw = HardwareConfig::paper();
    let acc = Accelerator::new(hw);
    let l = cnn::layer("conv2").unwrap();
    let bench = Bench::new("fig4_conv2");
    for (np, si) in [(4usize, 64usize), (2, 128), (1, 256)] {
        let run = RunConfig::square(np, si);
        bench.run(&format!("simulate_np{np}_si{si}"), || {
            acc.simulate(&run, l.m, l.k, l.n, &SimOptions::default()).unwrap()
        });
    }
}
