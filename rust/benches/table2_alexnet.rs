//! Table II regenerator: optimal ⟨N_p, S_i⟩ per AlexNet layer and the
//! simulated GFLOPS of the optimum vs the two pure-extension baselines
//! (Np=4 fixed, Np=1 fixed).
//!
//! Shape claims this must reproduce: the optimum beats both baselines on
//! every layer; fc layers approach peak (paper: 100.9 GFLOPS = 98.6%).

use multi_array::accelerator::{Accelerator, SimOptions};
use multi_array::cnn;
use multi_array::config::HardwareConfig;
use multi_array::dse;
use multi_array::util::Bench;

fn print_table() {
    let hw = HardwareConfig::paper();
    let acc = Accelerator::new(hw.clone());
    println!("\n=== Table II: optimal (Np, Si) per AlexNet layer ===");
    println!(
        "{:>8} {:>16} {:>10} | {:>9} {:>9} {:>9} | {:>6}",
        "Layer", "M*K*N", "Optimal", "Opt", "Np=4", "Np=1", "eff%"
    );
    for l in cnn::alexnet_layers() {
        let e = dse::explore(&hw, l.m, l.k, l.n, acc.surface()).unwrap();
        let opt = acc
            .simulate(&e.best.run, l.m, l.k, l.n, &SimOptions::default())
            .unwrap();
        let b4 = dse::baseline(&hw, 4, l.m, l.k, l.n, acc.surface()).unwrap();
        let s4 = acc.simulate(&b4.run, l.m, l.k, l.n, &SimOptions::default()).unwrap();
        let b1 = dse::baseline(&hw, 1, l.m, l.k, l.n, acc.surface()).unwrap();
        let s1 = acc.simulate(&b1.run, l.m, l.k, l.n, &SimOptions::default()).unwrap();
        println!(
            "{:>8} {:>16} {:>10} | {:>9.1} {:>9.1} {:>9.1} | {:>5.1}%",
            l.name,
            format!("{}*{}*{}", l.m, l.k, l.n),
            format!("({},{})", e.best.run.np, e.best.run.si),
            opt.gflops,
            s4.gflops,
            s1.gflops,
            100.0 * opt.efficiency(&hw),
        );
    }
    println!("peak = {:.1} GFLOPS\n", hw.peak_gflops());
}

fn main() {
    print_table();
    let hw = HardwareConfig::paper();
    let acc = Accelerator::new(hw.clone());
    let bench = Bench::new("table2_alexnet").samples(20);
    for l in cnn::alexnet_layers() {
        bench.run(&format!("dse_plus_sim_{}", l.name), || {
            let e = dse::explore(&hw, l.m, l.k, l.n, acc.surface()).unwrap();
            acc.simulate(&e.best.run, l.m, l.k, l.n, &SimOptions::default())
                .unwrap()
        });
    }
}
