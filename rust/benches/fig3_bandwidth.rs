//! Fig. 3 regenerator: effective per-array memory bandwidth vs block
//! size, for N_p in {1, 2, 4}.
//!
//! Prints the figure's series (the paper's two observations: BW rises
//! with block size, falls with array count), then times the measurement
//! itself (the DDR-model hot loop).

use multi_array::ddr::{DdrConfig, DdrSim};
use multi_array::util::Bench;

fn print_figure() {
    let cfg = DdrConfig::vc709();
    println!("\n=== Fig. 3: effective per-array bandwidth (GB/s) ===");
    println!(
        "{:>8} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "Si", "Np=1", "Np=2", "Np=4", "hit(Np=1)", "hit(Np=4)"
    );
    for si in [8usize, 16, 32, 64, 128, 256, 512] {
        let p1 = DdrSim::block_bandwidth(&cfg, 1, si);
        let p2 = DdrSim::block_bandwidth(&cfg, 2, si);
        let p4 = DdrSim::block_bandwidth(&cfg, 4, si);
        println!(
            "{:>8} {:>10.2} {:>10.2} {:>10.2} {:>11.1}% {:>11.1}%",
            si,
            p1.per_master_gbps(),
            p2.per_master_gbps(),
            p4.per_master_gbps(),
            p1.row_hit_rate * 100.0,
            p4.row_hit_rate * 100.0,
        );
    }
    println!("peak = {:.1} GB/s (DDR3-1600 x64)\n", cfg.peak_gbps());
}

fn main() {
    print_figure();
    let cfg = DdrConfig::vc709();
    let bench = Bench::new("fig3_bandwidth");
    for np in [1usize, 2, 4] {
        bench.run(&format!("block_bandwidth_np{np}_si128"), || {
            DdrSim::block_bandwidth(&cfg, np, 128)
        });
    }
}
