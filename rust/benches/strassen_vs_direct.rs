//! Strassen vs direct execution through the serving runtime.
//!
//! Five modes over the same 256x256x256 problem on one persistent
//! 4-worker server:
//!
//! * `direct_server_256`     — one plain job (the baseline);
//! * `strassen_depth1_256`   — one forced recursion level, classic
//!   schedule: 7 leaf GEMMs submitted as a job group, combine on the
//!   host;
//! * `strassen_depth2_256`   — two forced classic levels (49 leaves);
//! * `strassen_winograd_256` — two forced levels on the Winograd
//!   schedule (15 combine ops per node instead of 18, leaf operands
//!   fused into the packer) with the parallel recursion walk;
//! * `strassen_model_256`    — the model-chosen cutoff (depth 0 at this
//!   size: 256³ sits far below the modeled crossover, so this measures
//!   the predictor declining to recurse).
//!
//! Annotations carry the acceptance-relevant facts into
//! `BENCH_strassen.json`: the model-chosen depth for the measured
//! problem and for a serving-scale 4096³/8192³ projection, the executed
//! depth, leaf-GEMM count, the measured per-level fan-out (7
//! sub-multiplies per node vs 8 for a direct quadrant split), the
//! combine-op and temp-materialization counters behind the Winograd
//! win, and — from fresh single-run servers so the lifetime-wide idle
//! figure is per-mode — `worker_idle_frac` for the parallel and
//! sequential depth-2 walks.

use multi_array::analytical::strassen_crossover;
use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::coordinator::{GemmJob, JobServer, NumericsEngine, ServerConfig};
use multi_array::gemm::Matrix;
use multi_array::strassen::{
    self, Cutoff, StrassenAlgo, StrassenConfig, DIRECT_SPLIT_FANOUT,
};
use multi_array::util::Bench;

const DIM: usize = 256;

fn server_config(run: RunConfig) -> ServerConfig {
    ServerConfig {
        workers: 4,
        queue_capacity: 64,
        batch_max_tasks: 0,
        batch_window: 1,
        cross_job_stealing: true,
        default_run: Some(run),
        ..ServerConfig::default()
    }
}

/// One depth-2 Winograd multiply on a fresh server; returns the
/// server's lifetime `worker_idle_frac`, which with a single run on a
/// fresh pool is that run's idle fraction.
fn depth2_idle_frac(
    hw: &HardwareConfig,
    run: RunConfig,
    a: &Matrix,
    b: &Matrix,
    parallel: bool,
) -> f64 {
    let srv = JobServer::new(hw.clone(), NumericsEngine::golden(), server_config(run))
        .expect("server construction");
    let cfg = StrassenConfig {
        cutoff: Cutoff::Depth(2),
        run: Some(run),
        parallel,
        ..StrassenConfig::default()
    };
    strassen::multiply(&srv, a, b, &cfg).expect("strassen multiply");
    let idle = srv.stats().worker_idle_frac;
    srv.shutdown();
    idle
}

fn main() {
    let bench = Bench::new("strassen_vs_direct");
    let hw = HardwareConfig::paper();
    let run = RunConfig::square(4, 64);
    let srv = JobServer::new(hw.clone(), NumericsEngine::golden(), server_config(run))
        .expect("server construction");

    let a = Matrix::random(DIM, DIM, 1);
    let b = Matrix::random(DIM, DIM, 2);
    let flops = 2 * (DIM as u64).pow(3);

    bench.run_throughput("direct_server_256", flops, || {
        let job = GemmJob { id: 0, a: a.clone().into(), b: b.clone().into(), run: Some(run) };
        srv.submit_blocking(job).expect("direct job")
    });

    // Evaluate the model once, outside any timed region, so the
    // direct-vs-Strassen comparison is not skewed by the sweep (forced
    // Cutoff::Depth runs skip it entirely; only strassen_model_256 pays
    // it in-loop, which is what that mode measures).
    let plan_256 = strassen_crossover(&hw, DIM, DIM, DIM, srv.surface()).expect("crossover");

    for (label, cutoff, algo) in [
        ("strassen_depth1_256", Cutoff::Depth(1), StrassenAlgo::Classic),
        ("strassen_depth2_256", Cutoff::Depth(2), StrassenAlgo::Classic),
        ("strassen_winograd_256", Cutoff::Depth(2), StrassenAlgo::Winograd),
        ("strassen_model_256", Cutoff::Model, StrassenAlgo::Winograd),
    ] {
        let cfg = StrassenConfig { cutoff, run: Some(run), algo, ..StrassenConfig::default() };
        let mut last = None;
        bench.run_throughput(label, flops, || {
            last = Some(strassen::multiply(&srv, &a, &b, &cfg).expect("strassen multiply"));
        });
        let r = last.expect("at least one sample ran");
        bench.annotate("model_chosen_depth", plan_256.depth as f64);
        bench.annotate("executed_depth", r.depth as f64);
        bench.annotate("leaf_gemms", r.leaf_gemms as f64);
        // Measured at every node: 7 sub-multiplies per recursion level,
        // vs the 8 a direct quadrant split would spawn.
        bench.annotate("sub_multiplies_per_level", if r.depth > 0 { r.fanout(0) } else { 1.0 });
        bench.annotate("direct_sub_multiplies_per_level", DIRECT_SPLIT_FANOUT as f64);
        bench.annotate("combine_ops_per_node", r.combine.ops_per_node());
        bench.annotate("temps_materialized", r.combine.temps_materialized as f64);
        bench.annotate("temps_avoided_by_fusion", r.combine.temps_avoided as f64);
        bench.annotate("arena_fresh_bytes", r.arena.fresh_bytes as f64);
        bench.annotate("arena_reuses", r.arena.reuses as f64);
    }

    // Worker occupancy of the depth-2 Winograd walk, parallel vs
    // sequential, each on a fresh single-run server so the lifetime-wide
    // idle fraction belongs to exactly one walk. The parallel walk keeps
    // all sibling leaf groups in flight, so its idle fraction should sit
    // at or below the sequential one.
    let idle_par = depth2_idle_frac(&hw, run, &a, &b, true);
    let idle_seq = depth2_idle_frac(&hw, run, &a, &b, false);
    bench.annotate("worker_idle_frac_parallel", idle_par);
    bench.annotate("worker_idle_frac_sequential", idle_seq);
    println!(
        "bench strassen_vs_direct/depth2_worker_idle_frac      parallel {idle_par:.4} \
         sequential {idle_seq:.4}"
    );

    // Where the model arms at serving scale (no execution — pure Eqs.
    // 3–9 + combine-traffic prediction on the calibrated surface).
    for dim in [4096usize, 8192] {
        let plan = strassen_crossover(&hw, dim, dim, dim, srv.surface()).expect("crossover");
        println!(
            "bench strassen_vs_direct/crossover_{dim}^3          model depth {} \
             (direct {:.3} s, strassen {:.3} s)",
            plan.depth, plan.t_direct, plan.t_chosen
        );
    }
    let plan = strassen_crossover(&hw, 8192, 8192, 8192, srv.surface()).expect("crossover");
    bench.annotate("model_depth_8192cubed", plan.depth as f64);

    srv.shutdown();
    if let Err(e) = bench.write_json("BENCH_strassen.json") {
        eprintln!("could not write BENCH_strassen.json: {e}");
    } else {
        println!("wrote BENCH_strassen.json");
    }
}
