//! Hot-path microbenchmarks for the §Perf pass: the pieces that bound
//! end-to-end throughput of the stack.
//!
//! * DDR model burst loop (bounds bandwidth calibration and Fig. 3);
//! * event-sim task loop (bounds every `simulate` call);
//! * stepped PE array (bounds the cross-validation tests);
//! * functional block task + WQM pop/steal (bounds the coordinator).

use multi_array::accelerator::{Accelerator, SimOptions};
use multi_array::blocking::BlockPlan;
use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::ddr::{DdrConfig, DdrSim, StreamPattern};
use multi_array::gemm::{self, Matrix};
use multi_array::mpe::LinearArray;
use multi_array::util::Bench;
use multi_array::wqm::Wqm;

fn main() {
    let bench = Bench::new("perf_hotpath");

    // DDR stream: 4096 chunks of 512 B.
    let cfg = DdrConfig::vc709();
    bench.run_throughput("ddr_sequential_4096x512B", 4096 * 512, || {
        DdrSim::measure_stream(&cfg, 1, 512, 4096, StreamPattern::Sequential)
    });

    // Event-driven simulator on the two Table II extremes.
    let acc = Accelerator::new(HardwareConfig::paper());
    for (name, m, k, n) in
        [("conv2", 128usize, 1200usize, 729usize), ("fc6", 128, 9216, 4096)]
    {
        bench.run(&format!("event_sim_{name}"), || {
            acc.simulate(&RunConfig::square(2, 128), m, k, n, &SimOptions::default())
                .unwrap()
        });
    }

    // Cycle-stepped PE array, one 64^3 task.
    let sa = Matrix::random(64, 64, 1);
    let sb = Matrix::random(64, 64, 2);
    let arr = LinearArray::new(64, 14);
    bench.run_throughput("pe_array_stepped_64cubed", 2 * 64 * 64 * 64, || {
        arr.execute_task(&sa, &sb, 64, 64)
    });

    // Functional block task (the golden engine's unit of work).
    let a = Matrix::random(128, 256, 3);
    let b = Matrix::random(256, 128, 4);
    bench.run_throughput("functional_block_128x256x128", 2 * 128 * 256 * 128, || {
        gemm::block_task(&a, &b, 0, 0, 128, 128)
    });

    // WQM drain with stealing, 4096 tasks over 4 queues.
    let plan = BlockPlan::new(4096, 64, 4096, 64, 64);
    bench.run("wqm_drain_4096_tasks", || {
        let mut wqm = Wqm::from_partition(plan.partition(4));
        let mut n = 0usize;
        'outer: loop {
            for q in 0..4 {
                if wqm.pop(q).is_some() {
                    n += 1;
                } else if wqm.is_empty() {
                    break 'outer;
                }
            }
        }
        n
    });
}
