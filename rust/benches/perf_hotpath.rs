//! Hot-path microbenchmarks for the §Perf pass: the pieces that bound
//! end-to-end throughput of the stack.
//!
//! * DDR model burst loop (bounds bandwidth calibration and Fig. 3);
//! * event-sim task loop (bounds every `simulate` call);
//! * stepped PE array (bounds the cross-validation tests);
//! * the packed-panel task product — the coordinator's actual unit of
//!   work — vs the scalar k-i-j reference it replaced;
//! * panel packing and the cache-blocked transpose (per-job setup);
//! * WQM drain through the lock-free `AtomicWqm`, single- and
//!   multi-threaded.
//!
//! Writes `BENCH_hotpath.json` with every measurement so before/after
//! numbers are recorded per run.

use multi_array::accelerator::{Accelerator, SimOptions};
use multi_array::blocking::BlockPlan;
use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::ddr::{DdrConfig, DdrSim, StreamPattern};
use multi_array::gemm::{self, DisjointBlocks, Dtype, Matrix, PackedPanels};
use multi_array::mpe::LinearArray;
use multi_array::util::Bench;
use multi_array::wqm::AtomicWqm;

fn main() {
    let bench = Bench::new("perf_hotpath");

    // DDR stream: 4096 chunks of 512 B.
    let cfg = DdrConfig::vc709();
    bench.run_throughput("ddr_sequential_4096x512B", 4096 * 512, || {
        DdrSim::measure_stream(&cfg, 1, 512, 4096, StreamPattern::Sequential)
    });

    // Event-driven simulator on the two Table II extremes.
    let acc = Accelerator::new(HardwareConfig::paper());
    for (name, m, k, n) in
        [("conv2", 128usize, 1200usize, 729usize), ("fc6", 128, 9216, 4096)]
    {
        bench.run(&format!("event_sim_{name}"), || {
            acc.simulate(&RunConfig::square(2, 128), m, k, n, &SimOptions::default())
                .unwrap()
        });
    }

    // Cycle-stepped PE array, one 64^3 task.
    let sa = Matrix::random(64, 64, 1);
    let sb = Matrix::random(64, 64, 2);
    let arr = LinearArray::new(64, 14);
    bench.run_throughput("pe_array_stepped_64cubed", 2 * 64 * 64 * 64, || {
        arr.execute_task(&sa, &sb, 64, 64)
    });

    // The coordinator's unit of work, old vs new:
    // scalar reference — per-task panel copies + k-i-j loop;
    let a = Matrix::random(128, 256, 3);
    let b = Matrix::random(256, 128, 4);
    let flops = 2u64 * 128 * 256 * 128;
    bench.run_throughput("functional_block_scalar_ref", flops, || {
        let sa = a.block(0, 0, 128, a.cols);
        let sb = b.block(0, 0, b.rows, 128);
        gemm::block_task(&sa, &sb, 0, 0, 128, 128)
    });
    // packed pipeline — pre-packed panels + register-blocked microkernel
    // streamed straight into C (what `run_job` executes per task).
    let plan = BlockPlan::new(128, 256, 128, 128, 128);
    let panels = PackedPanels::pack(a.view(), b.view(), &plan);
    let task = plan.task(0);
    let mut c = Matrix::zeros(128, 128);
    bench.run_throughput("functional_block_128x256x128", flops, || {
        let writer = DisjointBlocks::new(c.view_mut());
        // SAFETY: single-threaded; one writer per iteration.
        unsafe { gemm::task_product_into(&panels, &task, &writer) };
    });
    bench.annotate_str("dtype", "f32");
    // Same task with bf16-packed panels: half the panel bytes, the
    // widen-on-load microkernel accumulating in f32.
    let panels_bf16 = PackedPanels::pack_dtype(a.view(), b.view(), &plan, Dtype::Bf16);
    let mut c_bf16 = Matrix::zeros(128, 128);
    bench.run_throughput("functional_block_128x256x128_bf16", flops, || {
        let writer = DisjointBlocks::new(c_bf16.view_mut());
        // SAFETY: single-threaded; one writer per iteration.
        unsafe { gemm::task_product_into(&panels_bf16, &task, &writer) };
    });
    bench.annotate_str("dtype", "bf16");

    // Per-job setup costs the packed path amortizes over all tasks.
    bench.run("pack_panels_128x256x128", || {
        PackedPanels::pack(a.view(), b.view(), &plan)
    });
    let big = Matrix::random(1024, 1024, 5);
    bench.run_throughput("transpose_1024x1024", 1024 * 1024, || big.transpose());

    // WQM drain through the lock-free queues, 4096 tasks over 4 queues.
    let plan = BlockPlan::new(4096, 64, 4096, 64, 64);
    bench.run("wqm_drain_4096_tasks", || {
        let wqm = AtomicWqm::from_partition(plan.partition(4));
        let mut n = 0usize;
        loop {
            let mut claimed = false;
            for q in 0..4 {
                if wqm.pop(q).is_some() {
                    n += 1;
                    claimed = true;
                }
            }
            if !claimed {
                break;
            }
        }
        n
    });
    bench.run("wqm_drain_4096_tasks_4threads", || {
        let wqm = AtomicWqm::from_partition(plan.partition(4));
        let mut total = 0usize;
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for q in 0..4 {
                let wqm = &wqm;
                handles.push(s.spawn(move || {
                    let mut n = 0usize;
                    while wqm.pop(q).is_some() {
                        n += 1;
                    }
                    n
                }));
            }
            for h in handles {
                total += h.join().unwrap();
            }
        });
        total
    });

    if let Err(e) = bench.write_json("BENCH_hotpath.json") {
        eprintln!("could not write BENCH_hotpath.json: {e}");
    } else {
        println!("wrote BENCH_hotpath.json");
    }
}
