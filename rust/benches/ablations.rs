//! Ablation benches for the design choices the paper motivates but does
//! not quantify in a dedicated table:
//!
//! 1. **Transpose-of-A** (§III-C): burst-friendly column fetches vs the
//!    naive strided access of row-major A.
//! 2. **Work stealing** (§III-B): total time and imbalance with the WQM
//!    controller on vs a static partition, under bandwidth skew.
//! 3. **Eq. 9 pruning** (§IV): how many design points the constraint
//!    removes, and that it never removes the winner.
//! 4. **Cooperation mode** (§III-A): the same problem on chained vs
//!    independent arrays at the block size only chaining can support.

use multi_array::accelerator::{Accelerator, SimOptions};
use multi_array::analytical;
use multi_array::blocking::BlockPlan;
use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::ddr::DdrConfig;
use multi_array::mac::{Mac, ProblemLayout};
use multi_array::util::Bench;

fn ablation_transpose() {
    println!("\n=== Ablation 1: transpose-of-A (Section III-C) ===");
    let l = ProblemLayout::contiguous(0, 128, 1200, 729, 4);
    let task = BlockPlan::new(128, 1200, 729, 128, 128).task(0);
    let mut mac = Mac::new(DdrConfig::vc709());
    let good = mac.run_descriptor(&l.sa_descriptor(&task));
    let mut mac = Mac::new(DdrConfig::vc709());
    let bad = mac.run_descriptor(&l.untransposed_a_descriptor(&task));
    println!(
        "  SA_1 load (conv-2 block): transposed {} clk, untransposed {} clk -> {:.1}x speedup",
        good,
        bad,
        bad as f64 / good as f64
    );
}

fn ablation_stealing() {
    println!("\n=== Ablation 2: work stealing (Section III-B) ===");
    let acc = Accelerator::new(HardwareConfig::paper());
    let run = RunConfig::square(4, 64);
    for skew in [
        vec![1.0, 1.0, 1.0, 1.0],
        vec![1.0, 1.0, 0.5, 0.25],
        vec![1.0, 0.6, 0.3, 0.15],
    ] {
        let on = acc
            .simulate(
                &run,
                2048,
                512,
                2048,
                &SimOptions { stealing: true, bw_skew: Some(skew.clone()), ..Default::default() },
            )
            .unwrap();
        let off = acc
            .simulate(
                &run,
                2048,
                512,
                2048,
                &SimOptions { stealing: false, bw_skew: Some(skew.clone()), ..Default::default() },
            )
            .unwrap();
        println!(
            "  skew {:?}: ON {:.1} ms (imb {:.2}) vs OFF {:.1} ms (imb {:.2}) -> {:.2}x",
            skew,
            on.total_secs * 1e3,
            on.imbalance(),
            off.total_secs * 1e3,
            off.imbalance(),
            off.total_secs / on.total_secs
        );
    }
}

fn ablation_eq9() {
    println!("\n=== Ablation 3: Eq. 9 design-space pruning (Section IV) ===");
    let hw = HardwareConfig::paper();
    let sis: Vec<usize> = (1..=hw.total_pes() / 16).map(|i| i * 16).collect();
    let full = sis.len() * 3; // {1, 2, 4} unconstrained
    let pruned: usize = sis.iter().map(|&si| analytical::feasible_nps(&hw, si).len()).sum();
    println!(
        "  unconstrained points: {full}, Eq. 9-feasible: {pruned} ({:.0}% pruned)",
        100.0 * (full - pruned) as f64 / full as f64
    );
    // The pruned points are exactly those whose S_i exceeds the chained
    // array length — they are *unimplementable*, so the winner survives
    // by construction; assert it anyway on conv-2.
    let acc = Accelerator::new(hw.clone());
    let e = multi_array::dse::explore(&hw, 128, 1200, 729, acc.surface()).unwrap();
    assert!(analytical::feasible_nps(&hw, e.best.run.si).contains(&e.best.run.np));
    println!("  winner {} is Eq. 9-feasible (asserted)", e.best.run);
}

fn ablation_cooperation() {
    println!("\n=== Ablation 4: Cooperation mode (Section III-A) ===");
    let acc = Accelerator::new(HardwareConfig::paper());
    // fc6 at S_i = 128 needs a 128-PE array: only possible by chaining
    // (Np=2, Cooperation). Compare against the best Independent-mode
    // config (Np=4, S_i <= 64).
    let coop = acc
        .simulate(&RunConfig::square(2, 128), 128, 9216, 4096, &SimOptions::default())
        .unwrap();
    let indep = acc
        .simulate(&RunConfig::square(4, 64), 128, 9216, 4096, &SimOptions::default())
        .unwrap();
    println!(
        "  fc6: Cooperation (2,128) {:.1} GFLOPS vs Independent (4,64) {:.1} GFLOPS -> {:.2}x",
        coop.gflops,
        indep.gflops,
        coop.gflops / indep.gflops
    );
}

fn ablation_double_buffering() {
    println!("\n=== Ablation 5: double buffering (Section III-A, R_a) ===");
    let acc = Accelerator::new(HardwareConfig::paper());
    for (name, m, k, n) in [("conv2", 128, 1200, 729), ("fc6", 128, 9216, 4096)] {
        let run = RunConfig::square(2, 128);
        let on = acc.simulate(&run, m, k, n, &SimOptions::default()).unwrap();
        let off = acc
            .simulate(
                &run,
                m,
                k,
                n,
                &SimOptions { double_buffering: false, ..Default::default() },
            )
            .unwrap();
        println!(
            "  {name}: overlapped {:.1} GFLOPS vs serialized {:.1} GFLOPS -> {:.2}x",
            on.gflops,
            off.gflops,
            on.gflops / off.gflops
        );
    }
}

fn ablation_channels() {
    println!("\n=== Ablation 6: one vs two DDR channels (VC709 DIMMs) ===");
    use multi_array::ddr::DdrSim;
    println!(
        "{:>8} {:>22} {:>22}",
        "Si", "1ch GB/s (Np=1/2/4)", "2ch GB/s (Np=1/2/4)"
    );
    for si in [32usize, 128, 512] {
        let f = |c: &DdrConfig| {
            (1..=3)
                .map(|e| DdrSim::block_bandwidth(c, 1 << (e - 1), si).per_master_gbps())
                .map(|v| format!("{v:.1}"))
                .collect::<Vec<_>>()
                .join("/")
        };
        println!(
            "{:>8} {:>22} {:>22}",
            si,
            f(&DdrConfig::vc709()),
            f(&DdrConfig::vc709_dual())
        );
    }
    // Table II under the dual-channel memory system.
    let hw = HardwareConfig {
        ddr: DdrConfig::vc709_dual(),
        ..HardwareConfig::paper()
    };
    let acc = Accelerator::new(hw.clone());
    let l = multi_array::cnn::layer("conv2").unwrap();
    let e = multi_array::dse::explore(&hw, l.m, l.k, l.n, acc.surface()).unwrap();
    let sim = acc
        .simulate(&e.best.run, l.m, l.k, l.n, &SimOptions::default())
        .unwrap();
    println!(
        "  conv2 with 2 channels: optimum {} -> {:.1} GFLOPS (1ch gave 81.7)",
        e.best.run, sim.gflops
    );
}

fn main() {
    ablation_transpose();
    ablation_stealing();
    ablation_eq9();
    ablation_cooperation();
    ablation_double_buffering();
    ablation_channels();

    // Timing: the ablation sweeps themselves (guards against the
    // simulator becoming too slow to explore with).
    let bench = Bench::new("ablations");
    let acc = Accelerator::new(HardwareConfig::paper());
    bench.run("stealing_pair_2048", || {
        let opts = SimOptions {
            stealing: true,
            bw_skew: Some(vec![1.0, 1.0, 0.5, 0.25]),
            ..Default::default()
        };
        acc.simulate(&RunConfig::square(4, 64), 2048, 512, 2048, &opts)
            .unwrap()
    });
    println!();
}
