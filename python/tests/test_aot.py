"""AOT path: every artifact function lowers to parseable HLO text and the
task signature (C' = C + A @ B) is numerically correct before lowering."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


class TestTaskFunction:
    @pytest.mark.parametrize("si,kc,sj", [(32, 128, 32), (16, 128, 16)])
    def test_gemm_acc_accumulates(self, si, kc, sj):
        a, b, c = rand((si, kc)), rand((kc, sj), seed=1), rand((si, sj), seed=2)
        (got,) = aot.gemm_acc(si, kc, sj)(a, b, c)
        np.testing.assert_allclose(got, c + a @ b, rtol=1e-4, atol=1e-4)

    def test_chunked_k_equals_full(self):
        # Accumulating over K chunks — how the rust runtime threads C
        # through repeated executions — must equal the one-shot product.
        si, sj, kc = 16, 16, 128
        a, b = rand((si, 3 * kc)), rand((3 * kc, sj), seed=1)
        c = jnp.zeros((si, sj), jnp.float32)
        fn = aot.gemm_acc(si, kc, sj)
        for t in range(3):
            (c,) = fn(a[:, t * kc : (t + 1) * kc], b[t * kc : (t + 1) * kc], c)
        np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-4)


class TestLowering:
    def test_task_lowers_to_hlo_text(self):
        text = aot.lower_task(16, 128, 16)
        assert "HloModule" in text
        assert "f32[16,128]" in text

    def test_full_lowers_to_hlo_text(self):
        text = aot.lower_full(64)
        assert "HloModule" in text

    def test_manifest_shapes_cover_runtime_needs(self):
        # Every Table II layer must be executable through some task shape
        # (si == sj == a task block size, any K — chunked).
        sis = {si for si, _, sj in aot.TASK_SHAPES if si == sj}
        assert {128, 64, 32}.issubset(sis)


class TestArtifactsOnDisk:
    """Validate artifacts if `make artifacts` has already produced them."""

    def _manifest(self):
        import pathlib

        p = pathlib.Path(__file__).resolve().parents[2] / "artifacts/manifest.json"
        if not p.exists():
            pytest.skip("artifacts not built yet")
        return json.loads(p.read_text()), p.parent

    def test_manifest_files_exist(self):
        manifest, root = self._manifest()
        for entry in manifest["tasks"] + manifest["full"]:
            assert (root / entry["file"]).exists(), entry["file"]

    def test_alexnet_shapes_match_model(self):
        manifest, _ = self._manifest()
        assert manifest["alexnet"] == {
            k: list(v) for k, v in model.alexnet_gemm_shapes().items()
        }
