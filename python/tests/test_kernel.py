"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

The hypothesis sweeps are the core signal — they cover shapes, block sizes
(including S_i != S_j, the PSU case), and dtypes, exactly the degrees of
freedom the paper's PE control units add over prior fixed-block designs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import block_mm, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, dtype=jnp.float32, seed=0):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


class TestBlockMM:
    def test_square_one_block(self):
        a, b = rand((32, 32)), rand((32, 32), seed=1)
        got = block_mm.block_mm(a, b, block_si=32, block_sj=32, block_k=32)
        np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-5, atol=1e-5)

    def test_multi_block_grid(self):
        a, b = rand((64, 96)), rand((96, 128), seed=1)
        got = block_mm.block_mm(a, b, block_si=32, block_sj=32, block_k=32)
        np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-5, atol=1e-5)

    def test_asymmetric_blocks_psu_case(self):
        # S_i != S_j — the configuration the PSU exists for.
        a, b = rand((32, 64)), rand((64, 96), seed=2)
        got = block_mm.block_mm(a, b, block_si=16, block_sj=48, block_k=32)
        np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-5, atol=1e-5)

    def test_rejects_unpadded(self):
        a, b = rand((30, 32)), rand((32, 32))
        with pytest.raises(ValueError, match="pad"):
            block_mm.block_mm(a, b, block_si=16, block_sj=16, block_k=16)

    def test_rejects_contraction_mismatch(self):
        a, b = rand((32, 32)), rand((48, 32))
        with pytest.raises(ValueError, match="mismatch"):
            block_mm.block_mm(a, b, block_si=16, block_sj=16, block_k=16)

    def test_zero_matrix(self):
        a = jnp.zeros((32, 32), jnp.float32)
        b = rand((32, 32))
        got = block_mm.block_mm(a, b, block_si=16, block_sj=16, block_k=16)
        np.testing.assert_array_equal(got, jnp.zeros((32, 32)))

    def test_identity(self):
        a = jnp.eye(64, dtype=jnp.float32)
        b = rand((64, 64))
        got = block_mm.block_mm(a, b, block_si=32, block_sj=32, block_k=32)
        np.testing.assert_allclose(got, b, rtol=1e-6, atol=1e-6)

    @settings(deadline=None, max_examples=25)
    @given(
        mi=st.integers(1, 4),
        nj=st.integers(1, 4),
        kk=st.integers(1, 4),
        si=st.sampled_from([8, 16, 32]),
        sj=st.sampled_from([8, 16, 32]),
        sk=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, mi, nj, kk, si, sj, sk, seed):
        a = rand((mi * si, kk * sk), seed=seed)
        b = rand((kk * sk, nj * sj), seed=seed + 1)
        got = block_mm.block_mm(a, b, block_si=si, block_sj=sj, block_k=sk)
        np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-4)

    @settings(deadline=None, max_examples=8)
    @given(
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_dtypes(self, dtype, seed):
        a = rand((32, 32), dtype=dtype, seed=seed)
        b = rand((32, 32), dtype=dtype, seed=seed + 1)
        got = block_mm.block_mm(a, b, block_si=16, block_sj=16, block_k=16)
        want = ref.matmul(a, b)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(want, np.float32),
            rtol=tol,
            atol=tol,
        )


class TestRank1MM:
    """The literal Eq. 2 dataflow kernel."""

    def test_matches_ref(self):
        a, b = rand((16, 24)), rand((24, 16), seed=3)
        got = block_mm.rank1_mm(a, b, block_si=8, block_sj=8)
        np.testing.assert_allclose(
            got, ref.rank1_matmul(a, b), rtol=1e-5, atol=1e-5
        )

    def test_matches_block_mm(self):
        a, b = rand((16, 16)), rand((16, 16), seed=4)
        r1 = block_mm.rank1_mm(a, b, block_si=8, block_sj=8)
        bm = block_mm.block_mm(a, b, block_si=8, block_sj=8, block_k=8)
        np.testing.assert_allclose(r1, bm, rtol=1e-5, atol=1e-5)

    @settings(deadline=None, max_examples=10)
    @given(
        mi=st.integers(1, 3),
        nj=st.integers(1, 3),
        k=st.integers(1, 24),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis(self, mi, nj, k, seed):
        a = rand((mi * 8, k), seed=seed)
        b = rand((k, nj * 8), seed=seed + 1)
        got = block_mm.rank1_mm(a, b, block_si=8, block_sj=8)
        np.testing.assert_allclose(
            got, ref.matmul(a, b), rtol=1e-4, atol=1e-4
        )


class TestRefOracle:
    def test_rank1_equals_matmul(self):
        a, b = rand((8, 12)), rand((12, 8), seed=5)
        np.testing.assert_allclose(
            ref.rank1_matmul(a, b), ref.matmul(a, b), rtol=1e-5, atol=1e-5
        )

    def test_pad_to(self):
        x = jnp.ones((3, 5))
        p = ref.pad_to(x, 8, 8)
        assert p.shape == (8, 8)
        assert float(p.sum()) == 15.0
