"""L2 correctness: padded GEMM, im2col conv lowering, Table II shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


class TestGemm:
    def test_exact_blocks(self):
        a, b = rand((64, 64)), rand((64, 64), seed=1)
        got = model.gemm(a, b, si=32, sj=32, sk=32)
        np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-4)

    def test_ragged_all_dims(self):
        # None of M, K, N divisible by the blocks — Section IV padding rule.
        a, b = rand((37, 53)), rand((53, 41), seed=1)
        got = model.gemm(a, b, si=16, sj=16, sk=16)
        assert got.shape == (37, 41)
        np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-4)

    def test_tall_skinny(self):
        a, b = rand((200, 7)), rand((7, 3), seed=2)
        got = model.gemm(a, b, si=64, sj=64, sk=64)
        np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-4)

    @settings(deadline=None, max_examples=20)
    @given(
        m=st.integers(1, 70),
        k=st.integers(1, 70),
        n=st.integers(1, 70),
        si=st.sampled_from([8, 16, 32]),
        sj=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_ragged(self, m, k, n, si, sj, seed):
        a, b = rand((m, k), seed=seed), rand((k, n), seed=seed + 1)
        got = model.gemm(a, b, si=si, sj=sj, sk=16)
        assert got.shape == (m, n)
        np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-4)


class TestPadding:
    def test_pad_to_blocks_shapes(self):
        a, b = rand((37, 53)), rand((53, 41))
        ap, bp = model.pad_to_blocks(a, b, 16, 16, 16)
        assert ap.shape == (48, 64)
        assert bp.shape == (64, 48)

    def test_pad_preserves_product(self):
        a, b = rand((10, 12)), rand((12, 9), seed=1)
        ap, bp = model.pad_to_blocks(a, b, 8, 8, 8)
        full = ref.matmul(ap, bp)
        np.testing.assert_allclose(
            full[:10, :9], ref.matmul(a, b), rtol=1e-5, atol=1e-5
        )
        # Padding region contributes zeros only.
        np.testing.assert_array_equal(np.asarray(full[10:, :]), 0.0)


class TestIm2col:
    def test_1x1_is_reshape(self):
        x = rand((3, 4, 4))
        col = model.im2col(x, 1, 1, 1, 0)
        np.testing.assert_array_equal(col, x.reshape(3, 16))

    def test_conv_matches_lax(self):
        x = rand((3, 11, 11))
        w = rand((8, 3, 3, 3), seed=1)
        got = model.conv2d_as_gemm(x, w, stride=2, pad=1, si=16, sj=16, sk=16)
        want = jax.lax.conv_general_dilated(
            x[None], w, (2, 2), [(1, 1), (1, 1)]
        )[0]
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(deadline=None, max_examples=10)
    @given(
        c=st.integers(1, 4),
        hw=st.integers(5, 12),
        f=st.integers(1, 6),
        kh=st.sampled_from([1, 3]),
        stride=st.sampled_from([1, 2]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_conv(self, c, hw, f, kh, stride, seed):
        x = rand((c, hw, hw), seed=seed)
        w = rand((f, c, kh, kh), seed=seed + 1)
        got = model.conv2d_as_gemm(x, w, stride=stride, pad=0, si=8, sj=8, sk=8)
        want = jax.lax.conv_general_dilated(
            x[None], w, (stride, stride), [(0, 0), (0, 0)]
        )[0]
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestAlexNetShapes:
    def test_table2_triples(self):
        shapes = model.alexnet_gemm_shapes()
        assert shapes["conv2"] == (128, 1200, 729)
        assert shapes["fc6"] == (128, 9216, 4096)
        assert len(shapes) == 8

    def test_conv1_shape_derivation(self):
        # conv-1: 96 filters, 3x11x11 kernels, 227x227 input, stride 4
        # -> M=96, K=3*11*11=363, N=55*55=3025 (Table II row 1).
        m, k, n = model.alexnet_gemm_shapes()["conv1"]
        assert (m, k, n) == (96, 3 * 11 * 11, 55 * 55)
