"""L1 transpose kernel (the MAC's Section III-C preprocessing) vs jnp.T."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import transpose

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


class TestTranspose:
    def test_square_single_tile(self):
        x = rand((16, 16))
        got = transpose.transpose(x, tile=16)
        np.testing.assert_array_equal(got, x.T)

    def test_rectangular_grid(self):
        x = rand((32, 64))
        got = transpose.transpose(x, tile=16)
        assert got.shape == (64, 32)
        np.testing.assert_array_equal(got, x.T)

    def test_rejects_unpadded(self):
        with pytest.raises(ValueError, match="tile"):
            transpose.transpose(rand((10, 16)), tile=16)

    def test_padded_wrapper_ragged(self):
        x = rand((37, 53))
        got = transpose.transpose_padded(x, tile=16)
        assert got.shape == (53, 37)
        np.testing.assert_array_equal(got, x.T)

    def test_involution(self):
        x = rand((32, 32))
        got = transpose.transpose(transpose.transpose(x, tile=16), tile=16)
        np.testing.assert_array_equal(got, x)

    @settings(deadline=None, max_examples=15)
    @given(
        m=st.integers(1, 70),
        n=st.integers(1, 70),
        tile=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_ragged(self, m, n, tile, seed):
        x = rand((m, n), seed=seed)
        got = transpose.transpose_padded(x, tile=tile)
        np.testing.assert_array_equal(got, x.T)
