"""L2 — the jax compute graph the accelerator executes.

The paper evaluates on AlexNet by converting each conv / fully-connected
layer to a single large GEMM (Cong & Xiao's im2col formulation, ref. [14]).
This module provides that graph:

* :func:`gemm` — padded block GEMM over the L1 Pallas kernel; the unit the
  MAC/WQM schedule as ``C_ij`` sub-block tasks.
* :func:`conv2d_as_gemm` — im2col lowering of a conv layer to ``gemm`` with
  the exact (M, K, N) the paper lists in Table II.
* :func:`alexnet_gemm_shapes` — the eight (M, K, N) triples of Table II,
  used by aot.py and cross-checked against rust/src/cnn.

Build-time only: ``aot.py`` lowers these functions once to HLO text; the
rust runtime executes the artifacts. Python never sits on the request path.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .kernels import block_mm as kernels
from .kernels import ref


def pad_to_blocks(
    a: jax.Array, b: jax.Array, si: int, sj: int, sk: int
) -> tuple[jax.Array, jax.Array]:
    """Zero-pad A (M x K) and B (K x N) to block multiples (Section IV)."""
    m, k = a.shape
    _, n = b.shape
    mp = math.ceil(m / si) * si
    np_ = math.ceil(n / sj) * sj
    kp = math.ceil(k / sk) * sk
    return ref.pad_to(a, mp, kp), ref.pad_to(b, kp, np_)


@functools.partial(jax.jit, static_argnames=("si", "sj", "sk"))
def gemm(
    a: jax.Array, b: jax.Array, *, si: int = 128, sj: int = 128, sk: int = 128
) -> jax.Array:
    """C = A @ B via the paper's blocked algorithm; pads then un-pads.

    ``si``/``sj`` are the paper's S_i/S_j block sizes; ``sk`` is the K-panel
    depth (the burst length analogue — the paper streams K un-tiled, we
    stream it in panels for VMEM residency; numerics are unchanged).
    """
    m, _ = a.shape
    _, n = b.shape
    ap, bp = pad_to_blocks(a, b, si, sj, sk)
    cp = kernels.block_mm(ap, bp, block_si=si, block_sj=sj, block_k=sk)
    return cp[:m, :n]


def im2col(
    x: jax.Array, kh: int, kw: int, stride: int, pad: int
) -> jax.Array:
    """Unroll (C, H, W) feature maps to the (C*kh*kw, oh*ow) GEMM operand.

    Column ``p`` holds the receptive field of output pixel ``p`` — the
    standard conv->GEMM lowering the paper adopts from ref. [14].
    """
    c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    # (C, kh, kw, oh, ow) patch tensor via dynamic slicing in a vmapped grid.
    ii = jnp.arange(oh) * stride
    jj = jnp.arange(ow) * stride

    def patch(i, j):
        return jax.lax.dynamic_slice(xp, (0, i, j), (c, kh, kw))

    patches = jax.vmap(lambda i: jax.vmap(lambda j: patch(i, j))(jj))(ii)
    # (oh, ow, C, kh, kw) -> (C*kh*kw, oh*ow)
    return patches.transpose(2, 3, 4, 0, 1).reshape(c * kh * kw, oh * ow)


def conv2d_as_gemm(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    pad: int = 0,
    si: int = 128,
    sj: int = 128,
    sk: int = 128,
) -> jax.Array:
    """Conv layer as GEMM: W (F, C*kh*kw) @ im2col(x) -> (F, oh*ow).

    For AlexNet conv-i this produces exactly the Table II M*K*N problem
    (M = filters, K = C*kh*kw, N = oh*ow).
    """
    f, c, kh, kw = w.shape
    a = w.reshape(f, c * kh * kw)
    b = im2col(x, kh, kw, stride, pad)
    out = gemm(a, b, si=si, sj=sj, sk=sk)
    oh = (x.shape[1] + 2 * pad - kh) // stride + 1
    ow = (x.shape[2] + 2 * pad - kw) // stride + 1
    return out.reshape(f, oh, ow)


# Table II problem sizes: layer -> (M, K, N). Mirrored in rust/src/cnn;
# test_model.py asserts the two stay in sync via the generated artifact set.
ALEXNET_GEMM_SHAPES: dict[str, tuple[int, int, int]] = {
    "conv1": (96, 363, 3025),
    "conv2": (128, 1200, 729),
    "conv3": (384, 2304, 169),
    "conv4": (192, 1728, 169),
    "conv5": (128, 1728, 169),
    "fc6": (128, 9216, 4096),
    "fc7": (128, 4096, 4096),
    "fc8": (128, 4096, 1000),
}


def alexnet_gemm_shapes() -> dict[str, tuple[int, int, int]]:
    """The eight Table II (M, K, N) GEMM problems of AlexNet."""
    return dict(ALEXNET_GEMM_SHAPES)
