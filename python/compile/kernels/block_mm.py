"""L1 — Pallas kernel for the paper's block matrix-multiplication dataflow.

The paper's linear PE array computes a sub-block product
``C_ij = SA_i x SB_j`` as a sum of rank-1 updates (Eq. 2):

    C_ij = sum_k V_k (x) U_k        V_k = k-th column of SA_i  (length S_i)
                                    U_k = k-th row    of SB_j  (length S_j)

Each PE owns one row of the ``S_i x S_j`` accumulator (its local memory
``M_c``), holds one element of ``V_k`` in a double-buffered register ``R_a``
(reused ``S_j`` times), and streams ``U_k`` through the array FIFOs.

TPU adaptation (see DESIGN.md SS Hardware-Adaptation): the whole accumulator
block lives in VMEM (the union of the PEs' ``M_c`` memories), the K dimension
becomes the innermost grid axis so A/B *panels* stream HBM->VMEM exactly like
the MAC's burst descriptors, and the rank-1 update batch of ``KP`` steps is
expressed as an MXU ``dot`` over an ``(S_i, KP) x (KP, S_j)`` panel pair.
``KP`` (panel depth) is the analogue of the paper's burst length ``STR``.

All kernels are built with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; correctness is validated against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_mm_kernel(a_ref, b_ref, c_ref, *, n_k: int):
    """One grid step: accumulate a panel product into the C block.

    Grid is (M/S_i, N/S_j, K/KP); the k axis is innermost so the (i, j)
    accumulator block stays resident in VMEM while panels stream through —
    the Pallas mirror of the PE array keeping M_c local across the K loop.
    """
    k = pl.program_id(2)

    # First panel of a fresh (i, j) block: clear the accumulator (the PE's
    # M_c is written, not read, on iteration k = 1 of Eq. 2).
    @pl.when(k == 0)
    def _():
        c_ref[...] = jnp.zeros_like(c_ref)

    # The rank-1-update batch: (S_i, KP) @ (KP, S_j). f32 accumulation is
    # the FMAC's behaviour; preferred_element_type keeps it explicit.
    c_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(c_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_si", "block_sj", "block_k")
)
def block_mm(
    a: jax.Array,
    b: jax.Array,
    *,
    block_si: int = 128,
    block_sj: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Blocked matmul ``a @ b`` with the paper's (S_i, S_j) tiling.

    Shapes must be multiples of the block sizes — the coordinator (L3) and
    :func:`..model.pad_to_blocks` zero-pad exactly as Section IV prescribes
    ("we pad matrices A and B with zeros if M and N are not integer
    multiples of S_i and S_j").
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    if m % block_si or n % block_sj or k % block_k:
        raise ValueError(
            f"shapes {a.shape} @ {b.shape} not multiples of blocks "
            f"({block_si}, {block_sj}, {block_k}); pad first"
        )

    grid = (m // block_si, n // block_sj, k // block_k)
    return pl.pallas_call(
        functools.partial(_block_mm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            # A panel: row-block i, K-panel k. The index_map is the burst
            # descriptor: base ADDR = (i, k), BZ = (S_i, KP).
            pl.BlockSpec((block_si, block_k), lambda i, j, kk: (i, kk)),
            # B panel: K-panel k, column-block j.
            pl.BlockSpec((block_k, block_sj), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_si, block_sj), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


def _rank1_kernel(a_ref, b_ref, c_ref):
    """Literal Eq. 2 dataflow: one rank-1 update per grid step (KP = 1).

    Slower than :func:`block_mm` (no MXU batching) but it is the faithful
    cycle-for-cycle analogue of the PE pipeline: V_k broadcast down the
    array x U_k streamed across it. Kept as a teaching / cross-check
    kernel; tests assert it matches both ``ref.py`` and ``block_mm``.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        c_ref[...] = jnp.zeros_like(c_ref)

    v = a_ref[...]  # (S_i, 1)  — V_k held in the R_a registers
    u = b_ref[...]  # (1, S_j)  — U_k streamed through the FIFOs
    c_ref[...] += v * u  # each PE row: R_a reused S_j times


@functools.partial(jax.jit, static_argnames=("block_si", "block_sj"))
def rank1_mm(
    a: jax.Array, b: jax.Array, *, block_si: int = 8, block_sj: int = 8
) -> jax.Array:
    """Rank-1-update matmul — the un-batched PE-array dataflow."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    if m % block_si or n % block_sj:
        raise ValueError("pad M, N to block multiples first")

    grid = (m // block_si, n // block_sj, k)
    return pl.pallas_call(
        _rank1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_si, 1), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((1, block_sj), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_si, block_sj), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)
