"""L1 — Pallas kernel for the MAC's transpose of A (Section III-C).

The paper stores A transposed so that fetching a *column* of `SA_i`
(the `V_k` the PE array prefetches) is a contiguous burst. The transpose
itself is a one-time preprocessing pass the MAC performs when the host
uploads A; here it is the tiled-transpose Pallas kernel that would run
as part of the upload pipeline.

TPU adaptation: a `(T, T)` tile is staged through VMEM and written back
transposed — the classic shared-memory-tile trick of GPU transposes,
re-expressed with BlockSpec index maps (read block `(i, j)`, write block
`(j, i)`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


@functools.partial(jax.jit, static_argnames=("tile",))
def transpose(x: jax.Array, *, tile: int = 128) -> jax.Array:
    """Tiled transpose; dims must be multiples of `tile` (pad first —
    the MAC sizes its staging buffers to burst multiples anyway)."""
    m, n = x.shape
    if m % tile or n % tile:
        raise ValueError(f"shape {x.shape} not a multiple of tile {tile}")
    grid = (m // tile, n // tile)
    return pl.pallas_call(
        _transpose_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (i, j))],
        # The write side lands at the transposed block coordinate.
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=True,
    )(x)


def transpose_padded(x: jax.Array, *, tile: int = 128) -> jax.Array:
    """Transpose with automatic zero-padding and un-padding."""
    m, n = x.shape
    mp = -(-m // tile) * tile
    np_ = -(-n // tile) * tile
    xp = jnp.pad(x, ((0, mp - m), (0, np_ - n)))
    return transpose(xp, tile=tile)[:n, :m]
