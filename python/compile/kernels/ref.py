"""Pure-jnp oracles for the L1 kernels — the build-time correctness signal.

Every Pallas kernel in this package has a reference here written with no
Pallas, no tiling tricks: plain jnp so a reviewer can audit it in seconds.
pytest (``python/tests/``) asserts allclose between kernel and oracle across
a hypothesis sweep of shapes, block sizes and dtypes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with f32 accumulation — the FMAC's semantics."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def rank1_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Eq. 2 evaluated literally: C = sum_k outer(A[:, k], B[k, :]).

    Accumulation order matches the PE array (k ascending), so this is also
    the bit-for-bit oracle for the simulator's functional model.
    """

    def step(c, k):
        return c + jnp.outer(a[:, k], b[k, :]), None

    k_dim = a.shape[1]
    init = jnp.zeros((a.shape[0], b.shape[1]), dtype=jnp.float32)
    c, _ = jax.lax.scan(step, init, jnp.arange(k_dim))
    return c.astype(a.dtype)


def pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2-D array up to (rows, cols) — Section IV's padding rule."""
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))
