//! Work-stealing demonstration: why the WQM exists (Section III-B).
//!
//! The paper's motivating scenario: arrays do not finish in lock-step —
//! an array with fewer/faster tasks drains its queue early, and without
//! stealing it idles while loaded arrays grind on. We reproduce this by
//! skewing per-array effective bandwidth (as uneven DDR port routing
//! would) and comparing stealing on/off: total time, per-array finish
//! times, imbalance, and steal counts.
//!
//! ```sh
//! cargo run --release --example work_stealing_demo
//! ```

use multi_array::accelerator::{Accelerator, SimOptions};
use multi_array::config::{HardwareConfig, RunConfig};

fn main() -> anyhow::Result<()> {
    let hw = HardwareConfig::paper();
    let acc = Accelerator::new(hw.clone());
    let run = RunConfig::square(4, 64);
    let (m, k, n) = (2048usize, 512usize, 2048usize);
    println!(
        "problem {m}x{k}x{n} on {} — arrays with bandwidth skew [1.0, 1.0, 0.5, 0.25]",
        run
    );

    for (label, stealing) in [("work-stealing ON ", true), ("work-stealing OFF", false)] {
        let opts = SimOptions {
            stealing,
            bw_skew: Some(vec![1.0, 1.0, 0.5, 0.25]),
            trace: true,
            ..Default::default()
        };
        let r = acc.simulate(&run, m, k, n, &opts)?;
        println!(
            "\n{label}: total {:.3} ms, {:.1} GFLOPS, imbalance {:.3}",
            r.total_secs * 1e3,
            r.gflops,
            r.imbalance()
        );
        for (i, a) in r.arrays.iter().enumerate() {
            println!(
                "  array {i}: {:>4} tasks, finish {:>8.3} ms, stolen in/out {:>3}/{:>3}",
                a.tasks,
                a.finish_secs * 1e3,
                a.stolen_in,
                a.stolen_out
            );
        }
        // Timeline: '#' local task, 's' stolen task, '.' idle.
        print!("{}", multi_array::accelerator::trace::gantt(&r, 72));
    }

    // Symmetric bandwidth, ragged task count: stealing still smooths the
    // remainder tasks (ceil division leaves some arrays one task short).
    println!("\n--- symmetric bandwidth, ragged task grid ---");
    let (m, n) = (65 * 64, 3 * 64); // 65*3 = 195 tasks over 4 arrays
    for (label, stealing) in [("ON ", true), ("OFF", false)] {
        let opts = SimOptions { stealing, bw_skew: None, ..Default::default() };
        let r = acc.simulate(&run, m, k, n, &opts)?;
        println!(
            "stealing {label}: total {:.3} ms, imbalance {:.4}, steals {}",
            r.total_secs * 1e3,
            r.imbalance(),
            r.total_steals
        );
    }
    Ok(())
}
