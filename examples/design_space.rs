//! Design-space exploration walkthrough: how the Section IV model turns
//! a problem size into an optimal ⟨N_p, S_i⟩, and what the Eq. 7 bounds
//! look like across the whole feasible space (the Fig. 4 view, for any
//! problem you like).
//!
//! ```sh
//! cargo run --release --example design_space -- 128 1200 729
//! ```

use multi_array::accelerator::{Accelerator, SimOptions};
use multi_array::analytical;
use multi_array::config::HardwareConfig;
use multi_array::dse;

fn main() -> anyhow::Result<()> {
    let args: Vec<usize> = std::env::args()
        .skip(1)
        .map(|s| s.parse().expect("usage: design_space [M K N]"))
        .collect();
    let (m, k, n) = match args.as_slice() {
        [m, k, n] => (*m, *k, *n),
        [] => (128, 1200, 729), // conv-2, the paper's Fig. 4 subject
        _ => anyhow::bail!("usage: design_space [M K N]"),
    };

    let hw = HardwareConfig::paper();
    let acc = Accelerator::new(hw.clone());
    println!("problem: {m} x {k} x {n}  on Pm={} P={}", hw.pm, hw.p);

    // Step 1: Eq. 9 prunes the (N_p, S_i) space.
    println!("\nEq. 9 feasible N_p per S_i:");
    for si in [16usize, 32, 64, 128, 256] {
        println!("  S_i = {si:>3}: N_p in {:?}", analytical::feasible_nps(&hw, si));
    }

    // Step 2: the model evaluates every feasible point.
    let e = dse::explore(&hw, m, k, n, acc.surface())?;
    println!("\nmodel ranking (top 10 of {}):", e.points.len());
    println!(
        "{:>12} {:>10} {:>12} {:>12} {:>12} {:>8}",
        "(Np,Si)", "n_work", "lower(ms)", "upper(ms)", "est GFLOPS", "bound"
    );
    for p in e.points.iter().take(10) {
        println!(
            "{:>12} {:>10} {:>12.3} {:>12.3} {:>12.1} {:>8}",
            format!("({},{})", p.run.np, p.run.si),
            p.prediction.n_work,
            p.prediction.lower * 1e3,
            p.prediction.upper * 1e3,
            p.est_gflops,
            if p.prediction.memory_bound() { "mem" } else { "compute" }
        );
    }

    // Step 3: validate the choice in the cycle-level simulator.
    println!("\nsimulator check of the top 5:");
    for p in e.points.iter().take(5) {
        let sim = acc.simulate(&p.run, m, k, n, &SimOptions::default())?;
        println!(
            "  {:>10}: simulated {:.3} ms, {:.1} GFLOPS ({:.1}% of peak)",
            format!("({},{})", p.run.np, p.run.si),
            sim.total_secs * 1e3,
            sim.gflops,
            100.0 * sim.efficiency(&hw)
        );
    }
    println!("\nchosen optimum: {}", e.best.run);
    Ok(())
}
