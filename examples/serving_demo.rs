//! Serving-runtime demonstration: why cross-job work stealing exists.
//!
//! One persistent pool serves a burst of mixed-size GEMM jobs — a few
//! elephants among many single-task mice. With cross-job stealing off
//! the pool drains jobs one at a time (per-job-pool behaviour) and
//! small jobs idle most workers; with it on, idle workers pull tasks
//! from the fullest live job and the pool stays busy. Small jobs are
//! additionally coalesced into batched super-jobs.
//!
//! ```sh
//! cargo run --release --example serving_demo
//! ```

use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::coordinator::{GemmJob, JobServer, NumericsEngine, ServerConfig};
use multi_array::gemm::Matrix;

fn burst(srv: &JobServer, njobs: usize) -> anyhow::Result<()> {
    let mut futures = Vec::with_capacity(njobs);
    for j in 0..njobs {
        let seed = j as u64;
        let (a, b) = if j % 8 == 0 {
            (Matrix::random(512, 128, seed), Matrix::random(128, 512, seed + 900))
        } else {
            (Matrix::random(64, 32, seed), Matrix::random(32, 64, seed + 900))
        };
        futures.push(srv.submit_async(GemmJob {
            id: seed,
            a: a.into(),
            b: b.into(),
            run: Some(RunConfig::square(4, 64)),
        })?);
    }
    for f in futures {
        f.wait()?;
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let hw = HardwareConfig::paper();
    let njobs = 64;
    println!(
        "{njobs} mixed-size jobs (8 elephants 512x128x512 + 56 single-task mice 64x32x64)\n"
    );

    for (label, cross, batching) in [
        ("per-job pools (stealing OFF, batching OFF)", false, false),
        ("cross-job stealing ON, batching OFF", true, false),
        ("full system (stealing + batching)", true, true),
    ] {
        let cfg = ServerConfig {
            workers: 4,
            queue_capacity: njobs,
            batch_max_tasks: if batching { 4 } else { 0 },
            batch_window: if batching { 8 } else { 1 },
            cross_job_stealing: cross,
            default_run: None,
            ..ServerConfig::default()
        };
        let srv = JobServer::new(hw.clone(), NumericsEngine::golden(), cfg)?;
        let t0 = std::time::Instant::now();
        burst(&srv, njobs)?;
        let wall = t0.elapsed().as_secs_f64();
        let stats = srv.stats();
        println!("{label}:");
        println!("  wall {:.3}s  |  {stats}", wall);
        srv.shutdown();
        println!();
    }
    println!(
        "idle fraction drops when stealing crosses job boundaries: the mice\n\
         no longer serialize the pool behind themselves, exactly the paper's\n\
         inter-array argument lifted to inter-job scope."
    );
    Ok(())
}
