//! End-to-end driver: all eight AlexNet layers (the paper's Table II
//! workload) through the full three-layer stack.
//!
//! For every layer: the DSE picks the optimal ⟨N_p, S_i⟩ from the
//! analytical model, the coordinator partitions the GEMM into sub-block
//! tasks, N_p work-stealing workers execute the numerics through the
//! AOT-compiled JAX/Pallas artifacts on the PJRT runtime (golden engine
//! if artifacts are absent), and the cycle-level simulator reports the
//! FPGA-side time. Output is the Table II comparison plus a numerics
//! check per layer. Recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example alexnet_e2e
//! ```

use multi_array::accelerator::SimOptions;
use multi_array::cnn;
use multi_array::config::HardwareConfig;
use multi_array::coordinator::{Coordinator, GemmJob, NumericsEngine};
use multi_array::dse;
use multi_array::gemm::Matrix;

fn main() -> anyhow::Result<()> {
    let hw = HardwareConfig::paper();
    let engine = NumericsEngine::auto("artifacts");
    println!(
        "accelerator Pm={} P={} @ {} MHz, numerics = {}",
        hw.pm, hw.p, hw.freq_mhz, engine.name
    );
    let co = Coordinator::new(hw.clone(), engine);

    println!(
        "\n{:>8} {:>16} {:>9} | {:>9} {:>9} {:>9} | {:>10} {:>10}",
        "Layer", "M*K*N", "Optimal", "Opt", "Np=4", "Np=1", "max|err|", "host(s)"
    );
    let mut total_flops = 0u64;
    let mut total_sim = 0.0f64;
    for (idx, l) in cnn::alexnet_layers().into_iter().enumerate() {
        // Deterministic per-layer operands.
        let a = Matrix::random(l.m, l.k, idx as u64 * 2 + 1);
        let b = Matrix::random(l.k, l.n, idx as u64 * 2 + 2);
        let want = a.matmul(&b);

        // Optimal config via DSE; run the real job with it.
        let r = co.run_job(GemmJob { id: idx as u64, a: a.into(), b: b.into(), run: None })?;
        let err = r.c.max_abs_diff(&want);
        assert!(r.c.allclose(&want, 1e-3), "{}: numerics mismatch {err}", l.name);

        // Baselines, simulated at their best fixed-extension configs.
        let acc = co.accelerator();
        let b4 = dse::baseline(&hw, hw.pm, l.m, l.k, l.n, acc.surface())?;
        let s4 = acc.simulate(&b4.run, l.m, l.k, l.n, &SimOptions::default())?;
        let b1 = dse::baseline(&hw, 1, l.m, l.k, l.n, acc.surface())?;
        let s1 = acc.simulate(&b1.run, l.m, l.k, l.n, &SimOptions::default())?;

        println!(
            "{:>8} {:>16} {:>9} | {:>9.1} {:>9.1} {:>9.1} | {:>10.2e} {:>10.2}",
            l.name,
            format!("{}*{}*{}", l.m, l.k, l.n),
            format!("({},{})", r.run.np, r.run.si),
            r.sim.gflops,
            s4.gflops,
            s1.gflops,
            err,
            r.host_latency_secs,
        );
        total_flops += l.flops();
        total_sim += r.sim.total_secs;
    }

    println!(
        "\nwhole network: {:.2} GFLOP in {:.2} ms simulated -> {:.1} GFLOPS ({:.1}% of {:.1} peak)",
        total_flops as f64 / 1e9,
        total_sim * 1e3,
        total_flops as f64 / total_sim / 1e9,
        100.0 * total_flops as f64 / total_sim / 1e9 / hw.peak_gflops(),
        hw.peak_gflops()
    );
    println!("coordinator metrics: {}", co.metrics().summary());
    Ok(())
}
