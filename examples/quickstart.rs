//! Quickstart: configure the paper's accelerator, run one GEMM through
//! the coordinator (PJRT numerics if `make artifacts` has run, golden
//! fallback otherwise), verify against the oracle, and print the
//! simulated FPGA performance.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::coordinator::{Coordinator, GemmJob, NumericsEngine};
use multi_array::gemm::Matrix;

fn main() -> anyhow::Result<()> {
    // The paper's Section V setup: Pm = 4 arrays of P = 64 PEs, 200 MHz.
    let hw = HardwareConfig::paper();
    println!(
        "accelerator: Pm={} P={} @ {} MHz  (peak {:.1} GFLOPS)",
        hw.pm,
        hw.p,
        hw.freq_mhz,
        hw.peak_gflops()
    );

    // PJRT backend when artifacts exist, golden numerics otherwise.
    let engine = NumericsEngine::auto("artifacts");
    println!("numerics backend: {}", engine.name);
    let co = Coordinator::new(hw.clone(), engine);

    // A 512x512x512 GEMM, pinned to the paper's favourite (2, 128).
    let a = Matrix::random(512, 512, 1);
    let b = Matrix::random(512, 512, 2);
    let want = a.matmul(&b);
    let job = GemmJob { id: 0, a: a.into(), b: b.into(), run: Some(RunConfig::square(2, 128)) };
    let r = co.run_job(job)?;

    println!("config used: {}", r.run);
    println!("max |err| vs oracle: {:.3e}", r.c.max_abs_diff(&want));
    println!(
        "simulated FPGA time: {:.3} ms -> {:.1} GFLOPS ({:.1}% of peak)",
        r.sim.total_secs * 1e3,
        r.sim.gflops,
        100.0 * r.sim.efficiency(&hw)
    );
    for (i, arr) in r.sim.arrays.iter().enumerate() {
        println!(
            "  array {i}: {} tasks, stolen in/out = {}/{}",
            arr.tasks, arr.stolen_in, arr.stolen_out
        );
    }
    assert!(r.c.allclose(&want, 1e-3), "numerics mismatch!");
    println!("OK");
    Ok(())
}
