#!/usr/bin/env python3
"""Bench-regression gate: fail CI when hot-path throughput drops.

Usage:
    check_bench_regression.py BASELINE.json FRESH.json [SERVING.json]

Compares `elements_per_sec` of the gated label in FRESH against the
checked-in BASELINE and fails (exit 1) on a drop of more than
MAX_DROP_FRAC. A baseline without the label (e.g. the placeholder
shipped before the first toolchain-enabled run) passes with a notice, so
the gate arms itself automatically once real numbers are committed.

When SERVING.json is given, also sanity-checks that the cross-job
stealing mode does not show a *higher* worker idle fraction than the
per-job-pool baseline; CI runners are noisy, so that check only warns.
"""

import json
import sys

GATED_LABEL = "functional_block_128x256x128"
MAX_DROP_FRAC = 0.20


def load_report(path):
    with open(path) as f:
        data = json.load(f)
    return data


def load_results(path):
    return {r.get("label"): r for r in load_report(path).get("results", [])}


def throughput(results, label):
    r = results.get(label)
    if r is None:
        return None
    return r.get("elements_per_sec")


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    baseline = load_results(argv[1])
    fresh = load_results(argv[2])

    fresh_tput = throughput(fresh, GATED_LABEL)
    if fresh_tput is None:
        print(f"FAIL: fresh run {argv[2]} did not emit '{GATED_LABEL}'")
        return 1

    base_tput = throughput(baseline, GATED_LABEL)
    if base_tput is None:
        print(
            f"NOTICE: baseline {argv[1]} has no '{GATED_LABEL}' entry yet "
            f"(fresh: {fresh_tput:.3e} elem/s). Gate passes; commit a "
            "baseline recorded with MARR_BENCH_QUICK=1 on a CI-class "
            "runner to arm it."
        )
        rc = 0
    else:
        base_quick = load_report(argv[1]).get("quick")
        fresh_quick = load_report(argv[2]).get("quick")
        if base_quick != fresh_quick:
            print(
                f"WARNING: baseline quick={base_quick} vs fresh "
                f"quick={fresh_quick} — different sampling modes; the "
                "comparison is biased. Re-record the baseline in the "
                "gate's mode (MARR_BENCH_QUICK=1)."
            )
        drop = (base_tput - fresh_tput) / base_tput
        print(
            f"{GATED_LABEL}: baseline {base_tput:.3e} elem/s, "
            f"fresh {fresh_tput:.3e} elem/s, drop {drop * 100:+.1f}%"
        )
        if drop > MAX_DROP_FRAC:
            print(f"FAIL: throughput dropped more than {MAX_DROP_FRAC * 100:.0f}%")
            return 1
        rc = 0

    if len(argv) > 3:
        serving = load_results(argv[3])
        pools = serving.get("serve64_per_job_pools", {}).get("worker_idle_frac")
        steal = serving.get("serve64_cross_steal", {}).get("worker_idle_frac")
        if pools is not None and steal is not None:
            print(
                f"serving idle fraction: per-job pools {pools:.3f}, "
                f"cross-job stealing {steal:.3f}"
            )
            if steal > pools:
                print(
                    "WARNING: cross-job stealing shows a higher idle fraction "
                    "than the per-job-pool baseline on this runner"
                )
        else:
            print("NOTICE: serving idle-fraction annotations missing; skipped")
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv))
