#!/usr/bin/env python3
"""Bench-regression gate: fail CI when gated throughputs drop.

Usage:
    check_bench_regression.py [--gate LABEL ...] [--max-drop-frac F]
                              BASELINE.json FRESH.json [SERVING.json]

Compares `elements_per_sec` of every gated label in FRESH against the
checked-in BASELINE and fails (exit 1) on a drop of more than
--max-drop-frac (default 0.20). The gate list is configurable:
repeat --gate to add labels; with no --gate flags it defaults to
DEFAULT_GATES. A baseline without a gated label (e.g. the placeholder
shipped before the first toolchain-enabled run) passes with a notice,
so each gate arms itself automatically once real numbers are committed.
A FRESH run missing a gated label always fails — the bench stopped
emitting a gated metric.

When SERVING.json is given, also sanity-checks that the cross-job
stealing mode does not show a *higher* worker idle fraction than the
per-job-pool baseline; CI runners are noisy, so that check only warns.
"""

import argparse
import json
import sys

DEFAULT_GATES = ["functional_block_128x256x128"]


def load_report(path):
    with open(path) as f:
        return json.load(f)


def load_results(path):
    return {r.get("label"): r for r in load_report(path).get("results", [])}


def throughput(results, label):
    r = results.get(label)
    if r is None:
        return None
    return r.get("elements_per_sec")


def check_label(label, baseline, fresh, base_path, fresh_path, max_drop):
    """Gate one label; returns False on a hard failure."""
    fresh_tput = throughput(fresh, label)
    if fresh_tput is None:
        print(f"FAIL: fresh run {fresh_path} did not emit '{label}'")
        return False
    base_tput = throughput(baseline, label)
    if base_tput is None:
        print(
            f"NOTICE: baseline {base_path} has no '{label}' entry yet "
            f"(fresh: {fresh_tput:.3e} elem/s). Gate passes; commit a "
            "baseline recorded with MARR_BENCH_QUICK=1 on a CI-class "
            "runner to arm it."
        )
        return True
    drop = (base_tput - fresh_tput) / base_tput
    print(
        f"{label}: baseline {base_tput:.3e} elem/s, "
        f"fresh {fresh_tput:.3e} elem/s, drop {drop * 100:+.1f}%"
    )
    if drop > max_drop:
        print(f"FAIL: '{label}' throughput dropped more than {max_drop * 100:.0f}%")
        return False
    return True


def check_serving(path):
    serving = load_results(path)
    pools = serving.get("serve64_per_job_pools", {}).get("worker_idle_frac")
    steal = serving.get("serve64_cross_steal", {}).get("worker_idle_frac")
    if pools is not None and steal is not None:
        print(
            f"serving idle fraction: per-job pools {pools:.3f}, "
            f"cross-job stealing {steal:.3f}"
        )
        if steal > pools:
            print(
                "WARNING: cross-job stealing shows a higher idle fraction "
                "than the per-job-pool baseline on this runner"
            )
    else:
        print("NOTICE: serving idle-fraction annotations missing; skipped")


def main(argv):
    parser = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    parser.add_argument(
        "--gate",
        action="append",
        metavar="LABEL",
        help=f"label to gate (repeatable; default: {DEFAULT_GATES})",
    )
    parser.add_argument("--max-drop-frac", type=float, default=0.20)
    parser.add_argument("baseline")
    parser.add_argument("fresh")
    parser.add_argument("serving", nargs="?")
    args = parser.parse_args(argv[1:])

    gates = args.gate if args.gate else DEFAULT_GATES
    baseline = load_results(args.baseline)
    fresh = load_results(args.fresh)

    # Only meaningful once a gated label is armed — an unarmed placeholder
    # baseline makes no comparison, so a mode mismatch is not noise-worthy.
    armed = any(throughput(baseline, label) is not None for label in gates)
    sampling = (load_report(args.baseline).get("quick"), load_report(args.fresh).get("quick"))
    if armed and None not in sampling and sampling[0] != sampling[1]:
        print(
            f"WARNING: baseline quick={sampling[0]} vs fresh "
            f"quick={sampling[1]} — different sampling modes; the "
            "comparison is biased. Re-record the baseline in the "
            "gate's mode (MARR_BENCH_QUICK=1)."
        )

    ok = True
    for label in gates:
        ok = check_label(
            label, baseline, fresh, args.baseline, args.fresh, args.max_drop_frac
        ) and ok

    if args.serving:
        check_serving(args.serving)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv))
