#!/usr/bin/env python3
"""Bench-regression gate: fail CI when gated throughputs drop.

Usage:
    check_bench_regression.py [--gate LABEL ...] [--max-drop-frac F]
                              BASELINE.json FRESH.json [SERVING.json]
    check_bench_regression.py --self-test

Compares `elements_per_sec` of every gated label in FRESH against the
checked-in BASELINE and fails (exit 1) on a drop of more than
--max-drop-frac (default 0.20). The gate list is configurable:
repeat --gate to add labels; with no --gate flags it defaults to
DEFAULT_GATES. A baseline without a gated label (e.g. the placeholder
shipped before the first toolchain-enabled run) passes with a notice,
so each gate arms itself automatically once real numbers are committed.
A FRESH run missing a gated label always fails — the bench stopped
emitting a gated metric.

Records may carry a `dtype` string annotation (e.g. the hotpath bench
tags `functional_block_128x256x128` with "f32" and its `_bf16` sibling
with "bf16"), so one script gates every precision variant: each label
is compared against the baseline record of the *same* label, and a
dtype annotation disagreement between the two is a hard FAIL — it means
the label was silently rebound to a different precision, which would
let a slow f32 run pass against a fast bf16 baseline (or vice versa).
A baseline record without a dtype tag (recorded before tagging) pairs
with any fresh dtype and passes with a notice until re-recorded.

When SERVING.json is given, also sanity-checks that the cross-job
stealing mode does not show a *higher* worker idle fraction than the
per-job-pool baseline; CI runners are noisy, so that check only warns.

--self-test runs the built-in gate scenarios (no files needed) and
exits 0 only if every scenario behaves as specified above.
"""

import argparse
import json
import sys

DEFAULT_GATES = ["functional_block_128x256x128"]


def load_report(path):
    with open(path) as f:
        return json.load(f)


def load_results(path):
    return {r.get("label"): r for r in load_report(path).get("results", [])}


def throughput(results, label):
    r = results.get(label)
    if r is None:
        return None
    return r.get("elements_per_sec")


def check_label(label, baseline, fresh, base_path, fresh_path, max_drop):
    """Gate one label; returns False on a hard failure."""
    fresh_tput = throughput(fresh, label)
    if fresh_tput is None:
        print(f"FAIL: fresh run {fresh_path} did not emit '{label}'")
        return False
    base_tput = throughput(baseline, label)
    if base_tput is None:
        print(
            f"NOTICE: baseline {base_path} has no '{label}' entry yet "
            f"(fresh: {fresh_tput:.3e} elem/s). Gate passes; commit a "
            "baseline recorded with MARR_BENCH_QUICK=1 on a CI-class "
            "runner to arm it."
        )
        return True
    base_dtype = baseline[label].get("dtype")
    fresh_dtype = fresh[label].get("dtype")
    if base_dtype is not None and fresh_dtype is not None and base_dtype != fresh_dtype:
        print(
            f"FAIL: '{label}' dtype mismatch — baseline tagged "
            f"{base_dtype!r}, fresh tagged {fresh_dtype!r}; the label "
            "was rebound to a different precision, so the comparison "
            "is meaningless. Re-record the baseline."
        )
        return False
    if (base_dtype is None) != (fresh_dtype is None):
        tagged = fresh_dtype if base_dtype is None else base_dtype
        print(
            f"NOTICE: '{label}' dtype tag present on only one side "
            f"({tagged!r}); comparing anyway. Re-record the baseline to "
            "carry the tag."
        )
    drop = (base_tput - fresh_tput) / base_tput
    dt = f" [{fresh_dtype}]" if fresh_dtype else ""
    print(
        f"{label}{dt}: baseline {base_tput:.3e} elem/s, "
        f"fresh {fresh_tput:.3e} elem/s, drop {drop * 100:+.1f}%"
    )
    if drop > max_drop:
        print(f"FAIL: '{label}' throughput dropped more than {max_drop * 100:.0f}%")
        return False
    return True


def check_serving(path):
    serving = load_results(path)
    pools = serving.get("serve64_per_job_pools", {}).get("worker_idle_frac")
    steal = serving.get("serve64_cross_steal", {}).get("worker_idle_frac")
    if pools is not None and steal is not None:
        print(
            f"serving idle fraction: per-job pools {pools:.3f}, "
            f"cross-job stealing {steal:.3f}"
        )
        if steal > pools:
            print(
                "WARNING: cross-job stealing shows a higher idle fraction "
                "than the per-job-pool baseline on this runner"
            )
    else:
        print("NOTICE: serving idle-fraction annotations missing; skipped")


def self_test():
    """Exercise every gate behavior on synthetic reports; returns 0/1."""

    def rec(tput, dtype=None):
        r = {"elements_per_sec": tput}
        if dtype is not None:
            r["dtype"] = dtype
        return r

    label = "functional_block_128x256x128"
    bf16 = label + "_bf16"
    scenarios = [
        (
            "small drop passes",
            {label: rec(1.00e9, "f32")},
            {label: rec(0.90e9, "f32")},
            [label],
            True,
        ),
        (
            "big drop fails",
            {label: rec(1.00e9, "f32")},
            {label: rec(0.50e9, "f32")},
            [label],
            False,
        ),
        (
            "unarmed baseline passes (self-arming)",
            {},
            {label: rec(1.00e9, "f32")},
            [label],
            True,
        ),
        (
            "fresh missing a gated label fails",
            {label: rec(1.00e9, "f32")},
            {},
            [label],
            False,
        ),
        (
            "dtype mismatch on one label fails",
            {label: rec(1.00e9, "bf16")},
            {label: rec(1.00e9, "f32")},
            [label],
            False,
        ),
        (
            "untagged baseline pairs with tagged fresh",
            {label: rec(1.00e9)},
            {label: rec(0.95e9, "f32")},
            [label],
            True,
        ),
        (
            "f32 and bf16 labels gate side by side",
            {label: rec(1.00e9, "f32"), bf16: rec(1.60e9, "bf16")},
            {label: rec(0.95e9, "f32"), bf16: rec(1.55e9, "bf16")},
            [label, bf16],
            True,
        ),
        (
            "bf16 regression fails independently of f32",
            {label: rec(1.00e9, "f32"), bf16: rec(1.60e9, "bf16")},
            {label: rec(0.95e9, "f32"), bf16: rec(0.80e9, "bf16")},
            [label, bf16],
            False,
        ),
    ]
    failures = 0
    for name, baseline, fresh, gates, want_pass in scenarios:
        ok = True
        for g in gates:
            ok = check_label(g, baseline, fresh, "<baseline>", "<fresh>", 0.20) and ok
        verdict = "ok" if ok == want_pass else "SELF-TEST FAILURE"
        print(f"self-test [{name}]: gate {'passed' if ok else 'failed'} — {verdict}")
        if ok != want_pass:
            failures += 1
    if failures:
        print(f"self-test: {failures}/{len(scenarios)} scenarios misbehaved")
        return 1
    print(f"self-test: all {len(scenarios)} scenarios behaved")
    return 0


def main(argv):
    parser = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    parser.add_argument(
        "--gate",
        action="append",
        metavar="LABEL",
        help=f"label to gate (repeatable; default: {DEFAULT_GATES})",
    )
    parser.add_argument("--max-drop-frac", type=float, default=0.20)
    parser.add_argument(
        "--self-test",
        action="store_true",
        help="run the built-in gate scenarios and exit",
    )
    parser.add_argument("baseline", nargs="?")
    parser.add_argument("fresh", nargs="?")
    parser.add_argument("serving", nargs="?")
    args = parser.parse_args(argv[1:])

    if args.self_test:
        return self_test()
    if not args.baseline or not args.fresh:
        parser.error("BASELINE and FRESH reports are required (or --self-test)")

    gates = args.gate if args.gate else DEFAULT_GATES
    baseline = load_results(args.baseline)
    fresh = load_results(args.fresh)

    # Only meaningful once a gated label is armed — an unarmed placeholder
    # baseline makes no comparison, so a mode mismatch is not noise-worthy.
    armed = any(throughput(baseline, label) is not None for label in gates)
    sampling = (load_report(args.baseline).get("quick"), load_report(args.fresh).get("quick"))
    if armed and None not in sampling and sampling[0] != sampling[1]:
        print(
            f"WARNING: baseline quick={sampling[0]} vs fresh "
            f"quick={sampling[1]} — different sampling modes; the "
            "comparison is biased. Re-record the baseline in the "
            "gate's mode (MARR_BENCH_QUICK=1)."
        )

    ok = True
    for label in gates:
        ok = check_label(
            label, baseline, fresh, args.baseline, args.fresh, args.max_drop_frac
        ) and ok

    if args.serving:
        check_serving(args.serving)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv))
