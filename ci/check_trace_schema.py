#!/usr/bin/env python3
"""Validate the JSONL flight-recorder export from `marr trace --json`.

Reads JSONL job traces (one object per line) from stdin or a file and
checks the contract `TraceExporter::write_jsonl` promises:

* every line is a JSON object carrying the full field set;
* `terminal` is one of the known terminal names;
* stage timestamps that are present are monotonic
  (submit <= pop <= planned <= published <= first_task <= last_task
   <= done);
* for `done` jobs: all stage spans, the end-to-end latency, and the
  predicted/measured drift record are present, and the five stage
  spans telescope — they sum to the end-to-end latency within
  tolerance;
* `workers[]` tallies are consistent: per-worker tasks sum to `tasks`,
  stolen counts sum to `stolen_tasks`, and stolen <= tasks everywhere.

Exit code 0 on success, 1 with a per-line diagnosis otherwise.

Usage:
    marr trace --json | python3 ci/check_trace_schema.py
    python3 ci/check_trace_schema.py traces.jsonl
"""

import json
import sys

REQUIRED_FIELDS = [
    "uid",
    "tenant",
    "terminal",
    "submit_us",
    "pop_us",
    "planned_us",
    "published_us",
    "first_task_us",
    "last_task_us",
    "done_us",
    "queue_secs",
    "plan_secs",
    "pack_secs",
    "execute_secs",
    "finalize_secs",
    "e2e_secs",
    "predicted_secs",
    "measured_secs",
    "drift_frac",
    "tasks",
    "stolen_tasks",
    "workers",
]

TERMINALS = {"done", "quota_rejected", "shed", "plan_failed", "failed", "in_flight"}

STAGE_ORDER = [
    "submit_us",
    "pop_us",
    "planned_us",
    "published_us",
    "first_task_us",
    "last_task_us",
    "done_us",
]

STAGE_SPANS = ["queue_secs", "plan_secs", "pack_secs", "execute_secs", "finalize_secs"]

# Stage spans are derived from the same microsecond stamps as the
# end-to-end latency, so the telescoped sum should agree to rounding.
SUM_TOL_SECS = 5e-5


def check_trace(t, errors):
    for f in REQUIRED_FIELDS:
        if f not in t:
            errors.append(f"missing field {f!r}")
    if errors:
        return

    if t["terminal"] not in TERMINALS:
        errors.append(f"unknown terminal {t['terminal']!r}")

    stamps = [(name, t[name]) for name in STAGE_ORDER if t[name] is not None]
    for (a_name, a), (b_name, b) in zip(stamps, stamps[1:]):
        if a > b:
            errors.append(f"timestamps not monotonic: {a_name}={a} > {b_name}={b}")

    workers = t["workers"]
    if not isinstance(workers, list):
        errors.append("workers is not a list")
        return
    for w in workers:
        for f in ("worker", "tasks", "stolen"):
            if f not in w:
                errors.append(f"worker tally missing {f!r}")
                return
        if w["stolen"] > w["tasks"]:
            errors.append(f"worker {w['worker']}: stolen {w['stolen']} > tasks {w['tasks']}")
    if sum(w["tasks"] for w in workers) != t["tasks"]:
        errors.append("per-worker tasks do not sum to `tasks`")
    if sum(w["stolen"] for w in workers) != t["stolen_tasks"]:
        errors.append("per-worker stolen do not sum to `stolen_tasks`")

    if t["terminal"] == "done":
        for f in STAGE_SPANS + ["e2e_secs", "predicted_secs", "measured_secs"]:
            if t[f] is None:
                errors.append(f"done job missing {f!r}")
        if all(t[f] is not None for f in STAGE_SPANS + ["e2e_secs"]):
            total = sum(t[f] for f in STAGE_SPANS)
            if abs(total - t["e2e_secs"]) > SUM_TOL_SECS:
                errors.append(
                    f"stage spans sum to {total:.6f}s but e2e is {t['e2e_secs']:.6f}s"
                )
        if t["tasks"] < 1:
            errors.append("done job executed zero tasks")


def main():
    src = open(sys.argv[1]) if len(sys.argv) > 1 else sys.stdin
    n = 0
    done = 0
    failed_lines = 0
    for lineno, line in enumerate(src, 1):
        line = line.strip()
        if not line:
            continue
        n += 1
        errors = []
        try:
            t = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"invalid JSON: {e}")
            t = None
        if t is not None:
            if not isinstance(t, dict):
                errors.append("line is not a JSON object")
            else:
                check_trace(t, errors)
                if not errors and t["terminal"] == "done":
                    done += 1
        if errors:
            failed_lines += 1
            uid = t.get("uid", "?") if isinstance(t, dict) else "?"
            for e in errors:
                print(f"line {lineno} (uid {uid}): {e}", file=sys.stderr)

    if n == 0:
        print("no job traces on input — is tracing enabled?", file=sys.stderr)
        sys.exit(1)
    if done == 0:
        print(f"{n} traces but none terminal=done — workload ran?", file=sys.stderr)
        sys.exit(1)
    if failed_lines:
        print(f"{failed_lines}/{n} traces failed validation", file=sys.stderr)
        sys.exit(1)
    print(f"ok: {n} job traces validated ({done} done)")


if __name__ == "__main__":
    main()
